//! §IV — temporal pipelining: computing `T` time-steps in one kernel
//! call, for any supported shape (1-D/2-D/3-D, star or box).
//!
//! Extra layers of compute workers are deployed along the time dimension;
//! layer `ℓ+1` receives its inputs *directly from the output streams of
//! layer `ℓ`* (no extra readers, no memory round-trip), and only the
//! final layer has writer workers. I/O happens at the pipeline boundary
//! only: the input grid is loaded exactly once regardless of depth.
//!
//! # The N-dim halo-growth trapezoid
//!
//! Semantics are the standard dependency trapezoid generalized to N
//! dimensions: layer `ℓ` (0-indexed) computes the box interior shrunk by
//! `radii * (ℓ+1)` along every axis — the set of step-`(ℓ+1)` values
//! fully determined by the original input without boundary values. Each
//! layer eats one radius of halo per axis, so the valid output box after
//! `T` steps is `[r*T, n - r*T)` per axis ([`valid_box`]): a trapezoid
//! in (space, time) whose slope is the stencil radius. The golden
//! reference is the iterated single-step oracle restricted to that box
//! ([`crate::verify::golden::stencil_ref_steps`]), and the fused result
//! is *bitwise* equal to it because every layer runs the same
//! [`StencilSpec::chain_taps`] MUL/MAC association order the oracle uses.
//!
//! # Structure per layer
//!
//! Layer 0 is fed by `w` readers streaming the whole grid row-major,
//! interleaved by column — exactly the `map1d`/`map2d`/`map3d` front
//! end. Every later layer is fed by the previous layer's per-worker
//! output streams, which are row-major over a *smaller* box, so the same
//! mandatory-buffering structure repeats with shrunken geometry:
//!
//! * each source stream flows through a **delay line** of copy PEs — one
//!   stream-row per stage, `2*ry` rows in 2-D, `2*rz` planes plus `2*ry`
//!   rows in 3-D (a plane of the layer-`ℓ` stream is `ny - 2*ry*ℓ` rows
//!   of it, shrinking with depth — the halo growth is visible in the
//!   buffer shapes);
//! * a tap with offset `(dz, dy, dx)` reads worker `(j + dx) mod w`'s
//!   line at stage `align - (dz*wy + dy)`, so every tap of an output
//!   fires at the same wall-time;
//! * tap filters use the row/col-id (2-D) or volume (3-D) scheme against
//!   the token tags. Tags ride the MAC chain unmodified from the chain's
//!   *last* tap, so a layer-`ℓ` output for point `P` is tagged
//!   `P + ℓ * o` where `o` is the last [`StencilSpec::chain_taps`]
//!   offset — a constant per-layer shift the filter windows absorb
//!   (`layer_tap_filter`). Every such tag is itself a valid grid
//!   point, so the flattened `z*ny + y` row encoding stays consistent.
//!
//! [`required_tokens`] is the capacity math for the whole pipeline
//! (delay lines + chain skew queues, per layer); `stencil::decomp` uses
//! it to search the deepest fused depth a tile's token budget admits.

use anyhow::{ensure, Result};

use crate::dfg::node::{AddrIter, FilterSpec, Op, Stage};
use crate::dfg::{Dsl, Graph};

use super::filter::{tap_reader, x_tap_reader};
use super::map1d::{tap_capacity_1d, QUEUE_SLACK};
use super::spec::StencilSpec;

/// Columns owned by worker `j` of layer `layer` (outputs of that layer):
/// `c ≡ j (mod w)` within `[rx*(layer+1), nx - rx*(layer+1))`.
fn layer_cols(spec: &StencilSpec, w: usize, layer: usize, j: usize) -> Vec<u32> {
    let r = spec.rx * (layer + 1);
    (r..spec.nx - r)
        .filter(|c| c % w == j % w)
        .map(|c| c as u32)
        .collect()
}

/// Bit-pattern filter selecting, from the output stream of layer
/// `layer-1` worker `rho`, the tokens layer `layer` worker `j`'s tap `t`
/// needs. Streams are ordered by ascending column, so the pattern is a
/// contiguous `0^m 1^n 0^p` window.
fn temporal_bits(
    spec: &StencilSpec,
    w: usize,
    layer: usize,
    _j: usize,
    t: usize,
    rho: usize,
) -> FilterSpec {
    let stream = layer_cols(spec, w, layer - 1, rho);
    // Needed columns: c = o + t - rx for o in layer `layer`'s range.
    let r = (spec.rx * (layer + 1)) as i64;
    let lo = r + t as i64 - spec.rx as i64;
    let hi = (spec.nx as i64 - r) + t as i64 - spec.rx as i64;
    let m = stream.iter().filter(|&&c| (c as i64) < lo).count() as u64;
    let n = stream
        .iter()
        .filter(|&&c| (c as i64) >= lo && (c as i64) < hi)
        .count() as u64;
    let p = stream.len() as u64 - m - n;
    FilterSpec::Bits { m, n, p }
}

/// Build a `steps`-deep temporal pipeline for a 1-D stencil with `w`
/// workers per layer. `steps = 1` degenerates to [`super::map1d::build`]'s
/// structure (modulo node names). Shape-generic callers should prefer
/// [`build_nd`], which delegates here for 1-D specs.
pub fn build(spec: &StencilSpec, w: usize, steps: usize) -> Result<Graph> {
    ensure!(spec.is_1d(), "temporal::build is 1-D only (use build_nd)");
    ensure!(steps >= 1, "need at least one time-step");
    let nx = spec.nx;
    let rx = spec.rx;
    ensure!(
        nx > 2 * rx * steps,
        "grid {nx} too small for {steps} time-steps of radius {rx}"
    );
    let taps = 2 * rx + 1;

    let mut d = Dsl::new();

    // Layer 0 readers.
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(rho as u32, w as u32, nx as u32))
            .out(&format!("l0.in{rho}"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("l0.in{rho}"))
            .out(&format!("l0.src{rho}"));
    }

    for layer in 0..steps {
        for j in 0..w {
            for t in 0..taps {
                let rho = x_tap_reader(j, t, rx, w);
                let (src, filt) = if layer == 0 {
                    (
                        format!("l0.src{rho}"),
                        super::filter::x_tap_bits(j, t, rx, w, nx),
                    )
                } else {
                    (
                        format!("l{}.out{rho}", layer - 1),
                        temporal_bits(spec, w, layer, j, t, rho),
                    )
                };
                d.op(&format!("l{layer}.w{j}.f{t}"), Op::Filter, Stage::Compute)
                    .worker(j)
                    .filter(filt)
                    .input(0, &src)
                    .out(&format!("l{layer}.w{j}.t{t}"));
            }
            d.op(&format!("l{layer}.w{j}.mul"), Op::Mul, Stage::Compute)
                .worker(j)
                .coeff(spec.cx[0])
                .input_cap(0, &format!("l{layer}.w{j}.t0"), tap_capacity_1d(rx, w, 0))
                .out(&format!("l{layer}.w{j}.p0"));
            for t in 1..taps {
                d.op(&format!("l{layer}.w{j}.mac{t}"), Op::Mac, Stage::Compute)
                    .worker(j)
                    .coeff(spec.cx[t])
                    .input(0, &format!("l{layer}.w{j}.p{}", t - 1))
                    .input_cap(1, &format!("l{layer}.w{j}.t{t}"), tap_capacity_1d(rx, w, t))
                    .out(&format!("l{layer}.w{j}.p{t}"));
            }
            // Publish this worker's layer output under the stream name the
            // next layer looks up; the final layer publishes to writers.
            d.op(&format!("l{layer}.w{j}.fan"), Op::Copy, Stage::Compute)
                .worker(j)
                .input(0, &format!("l{layer}.w{j}.p{}", taps - 1))
                .out(&format!("l{layer}.out{j}"));
        }
    }

    // Writers + sync for the final layer only (§IV: I/O at the pipeline
    // boundary).
    let last = steps - 1;
    for j in 0..w {
        let cols = layer_cols(spec, w, last, j);
        let count = cols.len() as u64;
        let first = cols.first().copied().unwrap_or(0);
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(
                first,
                w as u32,
                (nx - rx * steps) as u32,
            ))
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &format!("l{last}.out{j}"))
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }
    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

/// Final valid output range after `steps` time-steps (1-D view).
pub fn valid_range(spec: &StencilSpec, steps: usize) -> (usize, usize) {
    (spec.rx * steps, spec.nx - spec.rx * steps)
}

/// Valid output box after `steps` fused time-steps: `[lo, hi)` per axis
/// in `[x, y, z]` order — the grid shrunk by `radii * steps` per axis
/// (the N-dim dependency trapezoid; unused axes keep `[0, 1)`).
pub fn valid_box(spec: &StencilSpec, steps: usize) -> ([usize; 3], [usize; 3]) {
    let lo = [spec.rx * steps, spec.ry * steps, spec.rz * steps];
    let hi = [
        spec.nx.saturating_sub(spec.rx * steps),
        spec.ny.saturating_sub(spec.ry * steps),
        spec.nz.saturating_sub(spec.rz * steps),
    ];
    (lo, hi)
}

/// One out-box of a time-tiled boundary-ring stage: `[lo, hi)` per axis
/// in `[x, y, z]` order. See [`ring_band_boxes`].
pub type RingBox = ([usize; 3], [usize; 3]);

fn push_if_nonempty(boxes: &mut Vec<RingBox>, lo: [usize; 3], hi: [usize; 3]) {
    if (0..3).all(|a| lo[a] < hi[a]) {
        boxes.push((lo, hi));
    }
}

/// Time-tiled boundary-ring geometry (the trapezoid stages that make a
/// fused chunk correct on the **full** interior, not just [`valid_box`]).
///
/// The `T`-deep fused pipeline only writes `[r*T, n - r*T)`; the ring
/// between that box and the single-step interior `[r, n - r)` still
/// needs its `T` time-steps. Stage `s` (1-based, `s = 1..=T`) computes
/// the step-`s` values of **band** `s` = interior ∖ `B_s`, where `B_s`
/// keeps width `w_s^a = r_a * (2T - s)` per axis. The bands telescope:
///
/// * Stage `s+1` reads only points of band `s` plus grid-boundary points
///   (distance < `r` from the edge, which hold input values — exactly
///   the oracle's Dirichlet copy): a band-`(s+1)` point is within
///   `w_{s+1}^a = w_s^a - r_a` of the interior edge on some axis, so its
///   distance-≤`r` neighbors stay outside `B_s`.
/// * `w_T^a = r_a * T`, so band `T` = interior ∖ [`valid_box`] — exactly
///   the ring the fused graph leaves stale.
/// * At `T = 1`, `w_1^a = r_a` makes every band empty: unfused chunks
///   need no ring stages, automatically.
///
/// Each band decomposes onion-style into at most `2 * ndim` disjoint
/// boxes (z lo/hi slabs, then y, then x, shrinking the outer box after
/// each axis); axes with radius 0 contribute nothing. When the grid is
/// barely larger than `2 * r * T`, `B_s` clamps to empty and the band is
/// the whole interior — still handled by the same decomposition.
pub fn ring_band_boxes(spec: &StencilSpec, steps: usize, s: usize) -> Vec<RingBox> {
    assert!(s >= 1 && s <= steps, "stage {s} outside 1..={steps}");
    let dims = [spec.nx, spec.ny, spec.nz];
    let radii = [spec.rx, spec.ry, spec.rz];
    // Outer box: the single-step interior.
    let mut olo = [radii[0], radii[1], radii[2]];
    let mut ohi = [
        dims[0].saturating_sub(radii[0]),
        dims[1].saturating_sub(radii[1]),
        dims[2].saturating_sub(radii[2]),
    ];
    if (0..3).any(|a| olo[a] >= ohi[a]) {
        return Vec::new(); // empty interior: nothing to compute
    }
    let mut boxes = Vec::new();
    for a in (0..3).rev() {
        if radii[a] == 0 {
            continue; // unused axis: band and interior agree
        }
        let w = radii[a] * (2 * steps - s);
        let ilo = w.clamp(olo[a], ohi[a]);
        let ihi = dims[a].saturating_sub(w).clamp(ilo, ohi[a]);
        if ilo > olo[a] {
            let mut hi = ohi;
            hi[a] = ilo;
            push_if_nonempty(&mut boxes, olo, hi);
        }
        if ohi[a] > ihi {
            let mut lo = olo;
            lo[a] = ihi;
            push_if_nonempty(&mut boxes, lo, ohi);
        }
        olo[a] = ilo;
        ohi[a] = ihi;
    }
    boxes
}

/// Points in the boundary ring a `steps`-deep fused chunk leaves to the
/// time-tiled stages: the single-step interior minus [`valid_box`].
/// Zero at `steps = 1`.
pub fn ring_point_count(spec: &StencilSpec, steps: usize) -> usize {
    let dims = [spec.nx, spec.ny, spec.nz];
    let radii = [spec.rx, spec.ry, spec.rz];
    let ext = |lo: usize, n: usize| n.saturating_sub(2 * lo);
    let interior: usize = (0..3).map(|a| ext(radii[a], dims[a])).product();
    let valid: usize = (0..3).map(|a| ext(radii[a] * steps, dims[a])).product();
    interior.saturating_sub(valid)
}

/// Total FLOPs of one `steps`-deep fused application: layer `ℓ` computes
/// the interior shrunk by `radii * (ℓ+1)` per axis, so deeper layers do
/// slightly less work (the trapezoid tapers). `steps = 1` equals
/// [`StencilSpec::total_flops`].
pub fn total_flops(spec: &StencilSpec, steps: usize) -> f64 {
    let f = spec.flops_per_output();
    (1..=steps)
        .map(|l| {
            let pts = spec.nx.saturating_sub(2 * spec.rx * l)
                * spec.ny.saturating_sub(2 * spec.ry * l)
                * spec.nz.saturating_sub(2 * spec.rz * l);
            f * pts as f64
        })
        .sum()
}

/// Height (rows per plane) of the stream feeding `layer`: the whole grid
/// for layer 0, the previous layer's output window after.
fn stream_wy(spec: &StencilSpec, layer: usize) -> usize {
    spec.ny - 2 * spec.ry * layer
}

/// Delay-line alignment point of the stream feeding `layer` — the stage
/// every zero-offset tap reads, `rz*wy + ry` rows behind the stream head.
fn stream_align(spec: &StencilSpec, layer: usize) -> usize {
    spec.rz * stream_wy(spec, layer) + spec.ry
}

/// Delay-line stage a tap with offsets `(dz, dy)` reads at `layer`:
/// row distance from the most-delayed alignment point. Generalizes
/// [`super::map3d::tap_stage`] (its `layer = 0` case) to the shrunken
/// inter-layer streams.
pub fn delay_stage(spec: &StencilSpec, layer: usize, dz: i64, dy: i64) -> usize {
    let wy = stream_wy(spec, layer) as i64;
    (stream_align(spec, layer) as i64 - (dz * wy + dy)) as usize
}

/// Number of delay-line stages the stream feeding `layer` needs: the
/// deepest tap's stage (`2*ry` in 2-D; `2*rz*wy + ry` for a 3-D star,
/// `2*(rz*wy + ry)` for a 3-D box). Zero in 1-D.
pub fn delay_depth(spec: &StencilSpec, layer: usize) -> usize {
    spec.chain_taps()
        .iter()
        .map(|&(dz, dy, _, _)| delay_stage(spec, layer, dz, dy))
        .max()
        .unwrap_or(0)
}

/// `|{c ∈ [lo, hi) : c ≡ rho (mod w)}|`.
fn count_cols_in(lo: usize, hi: usize, rho: usize, w: usize) -> usize {
    let first = lo + ((rho % w) + w - (lo % w)) % w;
    if first >= hi {
        0
    } else {
        (hi - first - 1) / w + 1
    }
}

/// Tokens per stream-row of the stream feeding `layer`, for source
/// worker `rho` (layer 0: the raw reader interleave over the full row;
/// later: the previous layer's output columns).
pub fn stream_row_len(spec: &StencilSpec, w: usize, rho: usize, layer: usize) -> usize {
    let (lo, hi) = if layer == 0 {
        (0, spec.nx)
    } else {
        (spec.rx * layer, spec.nx - spec.rx * layer)
    };
    count_cols_in(lo, hi, rho, w)
}

/// Capacity of one delay-line stage of the stream feeding `layer`: one
/// stream-row plus slack (the §III-B mandatory-buffering unit, shrinking
/// with depth as the halo grows).
pub fn stage_capacity(spec: &StencilSpec, w: usize, rho: usize, layer: usize) -> usize {
    stream_row_len(spec, w, rho, layer) + QUEUE_SLACK
}

/// Capacity of the data queue feeding chain position `k` (0 = the MUL) —
/// the same systolic-skew formula every mapper layer uses.
pub fn chain_capacity(spec: &StencilSpec, w: usize, k: usize) -> usize {
    tap_capacity_1d(spec.rx, w, k)
}

/// Total mandatory on-fabric buffering (tokens) of a `steps`-deep fused
/// pipeline: per layer, the delay-line stages of its source streams plus
/// the chain skew queues. `steps = 1` equals the single-step mapper's
/// count ([`super::decomp::required_tokens`]); each extra layer adds a
/// strictly positive amount, so the quantity is monotone in depth —
/// which is what lets [`super::decomp::plan_fused`] search the deepest
/// depth a tile's token budget admits.
pub fn required_tokens(spec: &StencilSpec, w: usize, steps: usize) -> usize {
    let chain: usize = (0..spec.points()).map(|k| chain_capacity(spec, w, k)).sum();
    let mut total = 0;
    for layer in 0..steps {
        let depth = delay_depth(spec, layer);
        for rho in 0..w {
            total += depth * stage_capacity(spec, w, rho, layer);
        }
        total += w * chain;
    }
    total
}

/// Tag shift one layer applies: MAC-chain output tokens carry the tag of
/// the chain's *last* tap, so a layer-`ℓ` output for point `P` is tagged
/// `P + ℓ * o` with `o` the last [`StencilSpec::chain_taps`] offset.
fn tag_shift(spec: &StencilSpec) -> (i64, i64, i64) {
    let &(dz, dy, dx, _) = spec
        .chain_taps()
        .last()
        .expect("a stencil has at least one tap");
    (dz, dy, dx)
}

/// Row/col (2-D) or volume (3-D) filter for tap `(dz, dy, dx)` of layer
/// `layer`: pass tokens whose tag lies in the layer's output window
/// shifted by the tap offset *plus* the accumulated per-layer tag shift
/// (see [`tag_shift`]). Degenerates to the `map2d`/`map3d` tap filters
/// at `layer = 0`. All window bounds are provably in `[0, n]` per axis
/// (the shift never exceeds the halo the window already gave up), so the
/// `u32` casts cannot wrap.
fn layer_tap_filter(spec: &StencilSpec, layer: usize, dz: i64, dy: i64, dx: i64) -> FilterSpec {
    let (oz, oy, ox) = tag_shift(spec);
    let l = layer as i64;
    let (sz, sy, sx) = (dz + l * oz, dy + l * oy, dx + l * ox);
    let depth = (layer + 1) as i64;
    let (nx, ny, nz) = (spec.nx as i64, spec.ny as i64, spec.nz as i64);
    let (rx, ry, rz) = (spec.rx as i64, spec.ry as i64, spec.rz as i64);
    if spec.is_3d() {
        FilterSpec::Vol {
            z_lo: (rz * depth + sz) as u32,
            z_hi: (nz - rz * depth + sz) as u32,
            y_lo: (ry * depth + sy) as u32,
            y_hi: (ny - ry * depth + sy) as u32,
            col_lo: (rx * depth + sx) as u32,
            col_hi: (nx - rx * depth + sx) as u32,
            ny: spec.ny as u32,
        }
    } else {
        FilterSpec::RowCol {
            row_lo: (ry * depth + sy) as u32,
            row_hi: (ny - ry * depth + sy) as u32,
            col_lo: (rx * depth + sx) as u32,
            col_hi: (nx - rx * depth + sx) as u32,
        }
    }
}

/// Build a `steps`-deep temporal pipeline for any supported spec —
/// 1-D/2-D/3-D, star or box — with `w` workers per layer. 1-D specs
/// delegate to the bit-pattern [`build`]; 2-D/3-D layers repeat the
/// `map2d` row-buffer / `map3d` plane-buffer structure, fed from the
/// previous layer's output streams instead of readers. The input grid is
/// read exactly once; only the final layer stores, over [`valid_box`].
pub fn build_nd(spec: &StencilSpec, w: usize, steps: usize) -> Result<Graph> {
    ensure!(steps >= 1, "need at least one time-step");
    super::metrics::count_graph_build();
    if spec.is_1d() {
        return build(spec, w, steps);
    }
    ensure!(w >= 1, "need at least one worker");
    let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
    let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
    let dims = [nx, ny, nz];
    let radii = [rx, ry, rz];
    for a in 0..spec.ndim() {
        ensure!(
            dims[a] > 2 * radii[a] * steps,
            "axis {a} extent {} too small for {steps} time-steps of radius {}",
            dims[a],
            radii[a]
        );
    }
    let taps = spec.chain_taps();

    let mut d = Dsl::new();

    // Readers: stream the whole volume row-major, interleaved by column;
    // they are layer 0's source streams `s0.{rho}.d0`.
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter {
                row_lo: 0,
                row_hi: (nz * ny) as u32,
                col_start: rho as u32,
                col_hi: nx as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            })
            .out(&format!("r{rho}.addr"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("r{rho}.addr"))
            .out(&format!("s0.{rho}.d0"));
    }

    let last = steps - 1;
    for layer in 0..steps {
        // Delay line on each source stream — the same mandatory
        // buffering map2d/map3d hang behind readers, here also fed by
        // the previous layer's outputs.
        let depth = delay_depth(spec, layer);
        for rho in 0..w {
            let cap = stage_capacity(spec, w, rho, layer);
            for s in 1..=depth {
                d.op(&format!("s{layer}.{rho}.copy{s}"), Op::Copy, Stage::Reader)
                    .input_cap(0, &format!("s{layer}.{rho}.d{}", s - 1), cap)
                    .out(&format!("s{layer}.{rho}.d{s}"));
            }
        }
        for j in 0..w {
            let mut prev = String::new();
            for (k, &(dz, dy, dx, coeff)) in taps.iter().enumerate() {
                let rho = tap_reader(j, dx, rx, w);
                let stage = delay_stage(spec, layer, dz, dy);
                d.op(&format!("l{layer}.w{j}.f{k}"), Op::Filter, Stage::Compute)
                    .worker(j)
                    .filter(layer_tap_filter(spec, layer, dz, dy, dx))
                    .input(0, &format!("s{layer}.{rho}.d{stage}"))
                    .out(&format!("l{layer}.w{j}.t{k}"));
                // The chain's final output *is* the next layer's source
                // stream (or the writer feed on the last layer).
                let out = if k + 1 < taps.len() {
                    format!("l{layer}.w{j}.p{k}")
                } else if layer == last {
                    format!("l{layer}.w{j}.out")
                } else {
                    format!("s{}.{j}.d0", layer + 1)
                };
                let cap = chain_capacity(spec, w, k);
                if k == 0 {
                    d.op(&format!("l{layer}.w{j}.mul"), Op::Mul, Stage::Compute)
                        .worker(j)
                        .coeff(coeff)
                        .input_cap(0, &format!("l{layer}.w{j}.t{k}"), cap)
                        .out(&out);
                } else {
                    d.op(&format!("l{layer}.w{j}.mac{k}"), Op::Mac, Stage::Compute)
                        .worker(j)
                        .coeff(coeff)
                        .input(0, &prev)
                        .input_cap(1, &format!("l{layer}.w{j}.t{k}"), cap)
                        .out(&out);
                }
                prev = out;
            }
        }
    }

    // Writers + sync for the final layer only (§IV: I/O at the pipeline
    // boundary), over the valid box.
    let (col_lo, col_hi) = (rx * steps, nx - rx * steps);
    for j in 0..w {
        let first = super::first_output_col_at(j, w, col_lo);
        let per_row = count_cols_in(col_lo, col_hi, j, w);
        let count = (per_row * (ny - 2 * ry * steps) * (nz - 2 * rz * steps)) as u64;
        let agen = if spec.is_3d() {
            AddrIter::dim3(
                (rz * steps) as u32,
                (nz - rz * steps) as u32,
                (ry * steps) as u32,
                (ny - ry * steps) as u32,
                ny as u32,
                first as u32,
                col_hi as u32,
                w as u32,
                nx as u32,
            )
        } else {
            AddrIter {
                row_lo: (ry * steps) as u32,
                row_hi: (ny - ry * steps) as u32,
                col_start: first as u32,
                col_hi: col_hi as u32,
                col_stride: w as u32,
                width: nx as u32,
                y_lo: 0,
                y_hi: 0,
                ny: 0,
            }
        };
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(agen)
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &format!("l{last}.w{j}.out"))
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }
    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
    use crate::stencil::{decomp, map2d, map3d};

    fn spec3(nx: usize) -> StencilSpec {
        StencilSpec::dim1(nx, vec![0.25, 0.5, 0.25]).unwrap()
    }

    #[test]
    fn two_step_pipeline_has_two_compute_layers() {
        let g = build(&spec3(32), 2, 2).unwrap();
        // DP ops: 2 layers * 2 workers * 3 taps.
        assert_eq!(g.dp_ops(), 12);
        // Only the final layer writes.
        let h = g.op_histogram();
        assert_eq!(h[&Op::Store], 2);
        assert_eq!(h[&Op::Load], 2);
    }

    #[test]
    fn single_step_equals_map1d_dp_count() {
        let spec = spec3(24);
        let g1 = super::super::map1d::build(&spec, 3).unwrap();
        let gt = build(&spec, 3, 1).unwrap();
        assert_eq!(g1.dp_ops(), gt.dp_ops());
    }

    #[test]
    fn temporal_bits_select_contiguous_window() {
        let spec = spec3(20);
        // Layer 1 (rx=1): stream of layer-0 worker rho has cols ≡ rho in
        // [1, 19); needed for layer-1 worker j tap t: [2+t-1, 18+t-1).
        let f = temporal_bits(&spec, 1, 1, 0, 0, 0);
        if let FilterSpec::Bits { m, n, p } = f {
            // Stream cols 1..19 (18 tokens); needed cols [1, 17): m=0 n=16 p=2.
            assert_eq!((m, n, p), (0, 16, 2));
        } else {
            panic!("expected bits");
        }
    }

    #[test]
    fn rejects_too_many_steps() {
        assert!(build(&spec3(8), 1, 5).is_err());
        assert!(build_nd(&StencilSpec::heat2d(8, 8, 0.2), 1, 4).is_err());
    }

    #[test]
    fn valid_range_shrinks_linearly() {
        let spec = spec3(100);
        assert_eq!(valid_range(&spec, 1), (1, 99));
        assert_eq!(valid_range(&spec, 10), (10, 90));
        let (lo, hi) = valid_box(&spec, 10);
        assert_eq!((lo[0], hi[0]), (10, 90));
        assert_eq!((lo[1], hi[1]), (0, 1));
    }

    #[test]
    fn graph_validates_for_depths() {
        let spec = spec3(64);
        for steps in 1..=4 {
            let g = build(&spec, 2, steps).unwrap();
            assert!(crate::dfg::validate::check(&g).is_empty(), "steps={steps}");
        }
    }

    #[test]
    fn build_nd_delegates_for_1d() {
        let spec = spec3(48);
        let a = build(&spec, 2, 3).unwrap();
        let b = build_nd(&spec, 2, 3).unwrap();
        assert_eq!(a.dp_ops(), b.dp_ops());
        assert_eq!(a.node_count(), b.node_count());
    }

    #[test]
    fn build_nd_2d_structure() {
        // 5-pt star, 2 workers, 3 layers: 3 * 2 * 5 DP ops, one reader
        // pair per worker, stores only on the last layer.
        let spec = StencilSpec::heat2d(20, 14, 0.2);
        let g = build_nd(&spec, 2, 3).unwrap();
        assert_eq!(g.dp_ops(), 3 * 2 * 5);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Load], 2);
        assert_eq!(h[&Op::Store], 2);
        assert_eq!(h[&Op::Filter], 3 * 2 * 5);
        // Delay lines: 2*ry stages per stream per layer.
        assert_eq!(h[&Op::Copy], 3 * 2 * 2);
        assert!(crate::dfg::validate::check(&g).is_empty());
    }

    #[test]
    fn build_nd_3d_structure() {
        let spec = StencilSpec::heat3d(10, 8, 6, 0.1);
        let g = build_nd(&spec, 2, 2).unwrap();
        assert_eq!(g.dp_ops(), 2 * 2 * 7);
        let h = g.op_histogram();
        assert_eq!(h[&Op::Load], 2);
        assert_eq!(h[&Op::Store], 2);
        // Layer 0 line: 2*rz*ny + ry = 17; layer 1 stream has wy = 6:
        // 2*6 + 1 = 13. Two streams each.
        assert_eq!(delay_depth(&spec, 0), 17);
        assert_eq!(delay_depth(&spec, 1), 13);
        assert_eq!(h[&Op::Copy], 2 * (17 + 13));
        assert!(crate::dfg::validate::check(&g).is_empty());
    }

    #[test]
    fn sync_counts_cover_the_valid_box() {
        let spec = StencilSpec::heat2d(17, 11, 0.2);
        for (w, steps) in [(1usize, 2usize), (3, 2), (2, 3)] {
            let g = build_nd(&spec, w, steps).unwrap();
            let total: u64 = g
                .nodes
                .iter()
                .filter(|n| n.op == Op::SyncCount)
                .map(|n| n.expected.unwrap())
                .sum();
            let want = (spec.nx - 2 * steps) * (spec.ny - 2 * steps);
            assert_eq!(total, want as u64, "w={w} steps={steps}");
        }
    }

    #[test]
    fn delay_geometry_matches_single_step_mappers() {
        // Layer 0 of the generic pipeline is exactly the map2d/map3d
        // front end.
        let s2 = StencilSpec::dim2(21, 13, symmetric_taps(2), y_taps(3)).unwrap();
        assert_eq!(delay_depth(&s2, 0), 2 * s2.ry);
        for rho in 0..3 {
            assert_eq!(
                stage_capacity(&s2, 3, rho, 0),
                map2d::stage_capacity(&s2, rho, 3)
            );
        }
        let s3 = StencilSpec::heat3d(12, 7, 5, 0.1);
        assert_eq!(delay_depth(&s3, 0), map3d::delay_stages(&s3, 2));
        assert_eq!(delay_stage(&s3, 0, -1, 0), map3d::tap_stage(&s3, -1, 0));
        assert_eq!(delay_stage(&s3, 0, 0, 1), map3d::tap_stage(&s3, 0, 1));
    }

    #[test]
    fn required_tokens_single_step_equals_mapper_math() {
        let s1 = StencilSpec::dim1(64, symmetric_taps(2)).unwrap();
        let s2 = StencilSpec::heat2d(20, 14, 0.2);
        let s3 = StencilSpec::heat3d(10, 6, 5, 0.1);
        let b2 = StencilSpec::box2d(18, 12, 1, 2, uniform_box_taps(1, 2, 0)).unwrap();
        let b3 = StencilSpec::box3d(9, 7, 5, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
        for (spec, w) in [(&s1, 2usize), (&s2, 2), (&s3, 2), (&b2, 3), (&b3, 1)] {
            assert_eq!(
                required_tokens(spec, w, 1),
                decomp::required_tokens(spec, w),
                "dims {:?}",
                spec.dims()
            );
        }
    }

    #[test]
    fn required_tokens_monotone_in_depth() {
        let specs = [
            StencilSpec::dim1(80, symmetric_taps(2)).unwrap(),
            StencilSpec::heat2d(24, 18, 0.2),
            StencilSpec::dim3(14, 10, 8, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap(),
        ];
        for spec in &specs {
            for steps in 1..4 {
                assert!(
                    required_tokens(spec, 2, steps + 1) > required_tokens(spec, 2, steps),
                    "dims {:?} steps {steps}",
                    spec.dims()
                );
            }
        }
    }

    #[test]
    fn tag_shift_is_last_chain_tap() {
        assert_eq!(tag_shift(&spec3(10)), (0, 0, 1));
        assert_eq!(tag_shift(&StencilSpec::heat2d(10, 10, 0.2)), (0, 1, 0));
        assert_eq!(tag_shift(&StencilSpec::heat3d(8, 8, 8, 0.1)), (1, 0, 0));
        let b = StencilSpec::box2d(10, 10, 1, 2, uniform_box_taps(1, 2, 0)).unwrap();
        assert_eq!(tag_shift(&b), (0, 2, 1));
    }

    #[test]
    fn layer0_filters_match_map2d_scheme() {
        // At layer 0 the generic filter degenerates to the §III-B
        // row/col windows.
        let spec = StencilSpec::dim2(20, 12, symmetric_taps(2), y_taps(1)).unwrap();
        for (k, &(_, dy, dx, _)) in spec.chain_taps().iter().enumerate() {
            let f = layer_tap_filter(&spec, 0, 0, dy, dx);
            let want =
                super::super::filter::tap_rowcol(dy, dx, spec.rx, spec.ry, spec.nx, spec.ny);
            assert_eq!(f, want, "tap {k}");
        }
    }

    #[test]
    fn layer_filters_shift_by_accumulated_tag_offset() {
        // heat2d: o = (0, 1, 0). Layer 1 x-tap (dy=0, dx=0) window:
        // rows [2*1 + 0 + 1, 12 - 2 + 0 + 1) = [3, 11), cols [2, 18).
        let spec = StencilSpec::heat2d(20, 12, 0.2);
        let f = layer_tap_filter(&spec, 1, 0, 0, 0);
        assert_eq!(
            f,
            FilterSpec::RowCol { row_lo: 3, row_hi: 11, col_lo: 2, col_hi: 18 }
        );
        // The y = -1 tap window sits one row above.
        let f = layer_tap_filter(&spec, 1, 0, -1, 0);
        assert_eq!(
            f,
            FilterSpec::RowCol { row_lo: 2, row_hi: 10, col_lo: 2, col_hi: 18 }
        );
    }

    #[test]
    fn total_flops_matches_single_step_and_tapers() {
        let spec = StencilSpec::heat2d(20, 14, 0.2);
        assert_eq!(total_flops(&spec, 1), spec.total_flops());
        let t2 = total_flops(&spec, 2);
        assert!(t2 > spec.total_flops());
        assert!(t2 < 2.0 * spec.total_flops(), "deeper layers shrink");
    }

    /// All points of band `s`, flattened, in `(x, y, z)` form.
    fn band_points(spec: &StencilSpec, steps: usize, s: usize) -> Vec<(usize, usize, usize)> {
        let mut pts = Vec::new();
        for (lo, hi) in ring_band_boxes(spec, steps, s) {
            for z in lo[2]..hi[2] {
                for y in lo[1]..hi[1] {
                    for x in lo[0]..hi[0] {
                        pts.push((x, y, z));
                    }
                }
            }
        }
        pts
    }

    #[test]
    fn ring_bands_empty_for_unfused_chunks() {
        let specs = [
            spec3(30),
            StencilSpec::heat2d(18, 12, 0.2),
            StencilSpec::heat3d(10, 8, 6, 0.1),
        ];
        for spec in &specs {
            assert!(
                ring_band_boxes(spec, 1, 1).is_empty(),
                "dims {:?}",
                spec.dims()
            );
            assert_eq!(ring_point_count(spec, 1), 0);
        }
    }

    #[test]
    fn last_band_is_exactly_the_ring() {
        use std::collections::HashSet;
        let cases = [
            (spec3(30), 3usize),
            (StencilSpec::heat2d(20, 14, 0.2), 3),
            // nx = 7 clamps B_s to empty on x for the early stages.
            (StencilSpec::heat2d(7, 14, 0.2), 3),
            (StencilSpec::heat3d(12, 10, 8, 0.1), 2),
            (
                StencilSpec::box2d(16, 13, 1, 2, uniform_box_taps(1, 2, 0)).unwrap(),
                2,
            ),
        ];
        for (spec, steps) in &cases {
            let band = band_points(spec, *steps, *steps);
            let set: HashSet<_> = band.iter().copied().collect();
            assert_eq!(band.len(), set.len(), "overlapping boxes, dims {:?}", spec.dims());
            let (vlo, vhi) = valid_box(spec, *steps);
            let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
            let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
            let mut want = HashSet::new();
            for z in rz..nz - rz {
                for y in ry..ny - ry {
                    for x in rx..nx - rx {
                        let inside = (vlo[0]..vhi[0]).contains(&x)
                            && (vlo[1]..vhi[1]).contains(&y)
                            && (vlo[2]..vhi[2]).contains(&z);
                        if !inside {
                            want.insert((x, y, z));
                        }
                    }
                }
            }
            assert_eq!(set, want, "dims {:?} steps {steps}", spec.dims());
            assert_eq!(ring_point_count(spec, *steps), want.len());
        }
    }

    #[test]
    fn band_reads_stay_within_previous_band_or_boundary() {
        use std::collections::HashSet;
        let cases = [
            (StencilSpec::heat2d(20, 14, 0.2), 3usize),
            (StencilSpec::heat2d(7, 14, 0.2), 3),
            (StencilSpec::heat3d(12, 10, 8, 0.1), 2),
        ];
        for (spec, steps) in &cases {
            let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
            let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
            let interior = |x: usize, y: usize, z: usize| {
                (rx..nx - rx).contains(&x)
                    && (ry..ny - ry).contains(&y)
                    && (rz..nz - rz).contains(&z)
            };
            for s in 2..=*steps {
                let prev: HashSet<_> = band_points(spec, *steps, s - 1).iter().copied().collect();
                for (x, y, z) in band_points(spec, *steps, s) {
                    for dz in -(rz as i64)..=rz as i64 {
                        for dy in -(ry as i64)..=ry as i64 {
                            for dx in -(rx as i64)..=rx as i64 {
                                let q = (
                                    (x as i64 + dx) as usize,
                                    (y as i64 + dy) as usize,
                                    (z as i64 + dz) as usize,
                                );
                                assert!(
                                    !interior(q.0, q.1, q.2) || prev.contains(&q),
                                    "stage {s} point ({x},{y},{z}) reads {q:?} \
                                     outside band {} (dims {:?})",
                                    s - 1,
                                    spec.dims()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn ring_schedule_reproduces_the_oracle_on_the_ring() {
        // Host-execute the band schedule: one chain_taps-ordered step per
        // stage, restricted to that stage's boxes. After stage T the ring
        // must hold the step-T oracle values bitwise.
        let cases = [
            (spec3(30), 3usize),
            (StencilSpec::heat2d(20, 14, 0.2), 3),
            (StencilSpec::heat2d(7, 14, 0.2), 3),
            (StencilSpec::heat3d(12, 10, 8, 0.1), 2),
            (
                StencilSpec::box2d(16, 13, 1, 2, uniform_box_taps(1, 2, 0)).unwrap(),
                2,
            ),
        ];
        for (spec, steps) in &cases {
            let (nx, ny) = (spec.nx, spec.ny);
            let taps = spec.chain_taps();
            let input: Vec<f64> = (0..spec.grid_points())
                .map(|i| ((i * 37 % 101) as f64) * 0.25 - 12.0)
                .collect();
            let mut cur = input.clone();
            for s in 1..=*steps {
                let mut next = cur.clone();
                for (lo, hi) in ring_band_boxes(spec, *steps, s) {
                    for z in lo[2]..hi[2] {
                        for y in lo[1]..hi[1] {
                            for x in lo[0]..hi[0] {
                                let mut acc = 0.0;
                                for (k, &(dz, dy, dx, co)) in taps.iter().enumerate() {
                                    let zz = (z as i64 + dz) as usize;
                                    let yy = (y as i64 + dy) as usize;
                                    let xx = (x as i64 + dx) as usize;
                                    let v = co * cur[(zz * ny + yy) * nx + xx];
                                    if k == 0 {
                                        acc = v;
                                    } else {
                                        acc += v;
                                    }
                                }
                                next[(z * ny + y) * nx + x] = acc;
                            }
                        }
                    }
                }
                cur = next;
            }
            let want = crate::verify::golden::stencil_ref_steps(spec, &input, *steps);
            for (x, y, z) in band_points(spec, *steps, *steps) {
                let i = (z * ny + y) * nx + x;
                assert_eq!(
                    cur[i].to_bits(),
                    want[i].to_bits(),
                    "ring point ({x},{y},{z}) dims {:?} steps {steps}",
                    spec.dims()
                );
            }
        }
    }
}
