//! §IV — temporal pipelining: computing `T` time-steps in one kernel call.
//!
//! Extra layers of compute workers are deployed along the time dimension;
//! layer `ℓ+1` receives its inputs *directly from the output PEs of layer
//! `ℓ`* (no extra readers, no memory round-trip), and only the final layer
//! has writer workers. I/O happens at the pipeline boundary only.
//!
//! Semantics are the standard dependency trapezoid: layer `ℓ` computes
//! the columns `[rx*(ℓ+1), nx - rx*(ℓ+1))`, the set fully determined by
//! the original input without boundary values. The golden reference is
//! the iterated single-step map restricted to the final interior
//! (`verify::golden` checks exactly this).

use anyhow::{ensure, Result};

use crate::dfg::node::{AddrIter, FilterSpec, Op, Stage};
use crate::dfg::{Dsl, Graph};

use super::filter::x_tap_reader;
use super::map1d::tap_capacity_1d;
use super::spec::StencilSpec;

/// Columns owned by worker `j` of layer `layer` (outputs of that layer):
/// `c ≡ j (mod w)` within `[rx*(layer+1), nx - rx*(layer+1))`.
fn layer_cols(spec: &StencilSpec, w: usize, layer: usize, j: usize) -> Vec<u32> {
    let r = spec.rx * (layer + 1);
    (r..spec.nx - r)
        .filter(|c| c % w == j % w)
        .map(|c| c as u32)
        .collect()
}

/// Bit-pattern filter selecting, from the output stream of layer
/// `layer-1` worker `rho`, the tokens layer `layer` worker `j`'s tap `t`
/// needs. Streams are ordered by ascending column, so the pattern is a
/// contiguous `0^m 1^n 0^p` window.
fn temporal_bits(
    spec: &StencilSpec,
    w: usize,
    layer: usize,
    _j: usize,
    t: usize,
    rho: usize,
) -> FilterSpec {
    let stream = layer_cols(spec, w, layer - 1, rho);
    // Needed columns: c = o + t - rx for o in layer `layer`'s range.
    let r = (spec.rx * (layer + 1)) as i64;
    let lo = r + t as i64 - spec.rx as i64;
    let hi = (spec.nx as i64 - r) + t as i64 - spec.rx as i64;
    let m = stream.iter().filter(|&&c| (c as i64) < lo).count() as u64;
    let n = stream
        .iter()
        .filter(|&&c| (c as i64) >= lo && (c as i64) < hi)
        .count() as u64;
    let p = stream.len() as u64 - m - n;
    FilterSpec::Bits { m, n, p }
}

/// Build a `steps`-deep temporal pipeline for a 1-D stencil with `w`
/// workers per layer. `steps = 1` degenerates to [`super::map1d::build`]'s
/// structure (modulo node names).
pub fn build(spec: &StencilSpec, w: usize, steps: usize) -> Result<Graph> {
    ensure!(spec.is_1d(), "temporal pipeline implemented for 1-D stencils");
    ensure!(steps >= 1, "need at least one time-step");
    let nx = spec.nx;
    let rx = spec.rx;
    ensure!(
        nx > 2 * rx * steps,
        "grid {nx} too small for {steps} time-steps of radius {rx}"
    );
    let taps = 2 * rx + 1;

    let mut d = Dsl::new();

    // Layer 0 readers.
    for rho in 0..w {
        d.op(&format!("r{rho}.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(rho as u32, w as u32, nx as u32))
            .out(&format!("l0.in{rho}"));
        d.op(&format!("r{rho}.ld"), Op::Load, Stage::Reader)
            .input(0, &format!("l0.in{rho}"))
            .out(&format!("l0.src{rho}"));
    }

    for layer in 0..steps {
        for j in 0..w {
            for t in 0..taps {
                let rho = x_tap_reader(j, t, rx, w);
                let (src, filt) = if layer == 0 {
                    (
                        format!("l0.src{rho}"),
                        super::filter::x_tap_bits(j, t, rx, w, nx),
                    )
                } else {
                    (
                        format!("l{}.out{rho}", layer - 1),
                        temporal_bits(spec, w, layer, j, t, rho),
                    )
                };
                d.op(&format!("l{layer}.w{j}.f{t}"), Op::Filter, Stage::Compute)
                    .worker(j)
                    .filter(filt)
                    .input(0, &src)
                    .out(&format!("l{layer}.w{j}.t{t}"));
            }
            d.op(&format!("l{layer}.w{j}.mul"), Op::Mul, Stage::Compute)
                .worker(j)
                .coeff(spec.cx[0])
                .input_cap(0, &format!("l{layer}.w{j}.t0"), tap_capacity_1d(rx, w, 0))
                .out(&format!("l{layer}.w{j}.p0"));
            for t in 1..taps {
                d.op(&format!("l{layer}.w{j}.mac{t}"), Op::Mac, Stage::Compute)
                    .worker(j)
                    .coeff(spec.cx[t])
                    .input(0, &format!("l{layer}.w{j}.p{}", t - 1))
                    .input_cap(1, &format!("l{layer}.w{j}.t{t}"), tap_capacity_1d(rx, w, t))
                    .out(&format!("l{layer}.w{j}.p{t}"));
            }
            // Publish this worker's layer output under the stream name the
            // next layer looks up; the final layer publishes to writers.
            d.op(&format!("l{layer}.w{j}.fan"), Op::Copy, Stage::Compute)
                .worker(j)
                .input(0, &format!("l{layer}.w{j}.p{}", taps - 1))
                .out(&format!("l{layer}.out{j}"));
        }
    }

    // Writers + sync for the final layer only (§IV: I/O at the pipeline
    // boundary).
    let last = steps - 1;
    for j in 0..w {
        let cols = layer_cols(spec, w, last, j);
        let count = cols.len() as u64;
        let first = cols.first().copied().unwrap_or(0);
        d.op(&format!("w{j}.st.cu"), Op::AddrGen, Stage::Control)
            .agen(AddrIter::dim1(
                first,
                w as u32,
                (nx - rx * steps) as u32,
            ))
            .out(&format!("w{j}.staddr"));
        d.op(&format!("w{j}.st"), Op::Store, Stage::Writer)
            .worker(j)
            .input(0, &format!("w{j}.staddr"))
            .input(1, &format!("l{last}.out{j}"))
            .out(&format!("w{j}.ack"));
        d.op(&format!("w{j}.sync"), Op::SyncCount, Stage::Sync)
            .worker(j)
            .expected(count)
            .input(0, &format!("w{j}.ack"))
            .out(&format!("w{j}.done"));
    }
    let mut done = d.op("done", Op::DoneTree, Stage::Sync).expected(w as u64);
    for j in 0..w {
        done = done.input(j as u8, &format!("w{j}.done"));
    }
    drop(done);

    let g = d.build()?;
    crate::dfg::validate::validate(&g)?;
    Ok(g)
}

/// Final valid output range after `steps` time-steps.
pub fn valid_range(spec: &StencilSpec, steps: usize) -> (usize, usize) {
    (spec.rx * steps, spec.nx - spec.rx * steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec3(nx: usize) -> StencilSpec {
        StencilSpec::dim1(nx, vec![0.25, 0.5, 0.25]).unwrap()
    }

    #[test]
    fn two_step_pipeline_has_two_compute_layers() {
        let g = build(&spec3(32), 2, 2).unwrap();
        // DP ops: 2 layers * 2 workers * 3 taps.
        assert_eq!(g.dp_ops(), 12);
        // Only the final layer writes.
        let h = g.op_histogram();
        assert_eq!(h[&Op::Store], 2);
        assert_eq!(h[&Op::Load], 2);
    }

    #[test]
    fn single_step_equals_map1d_dp_count() {
        let spec = spec3(24);
        let g1 = super::super::map1d::build(&spec, 3).unwrap();
        let gt = build(&spec, 3, 1).unwrap();
        assert_eq!(g1.dp_ops(), gt.dp_ops());
    }

    #[test]
    fn temporal_bits_select_contiguous_window() {
        let spec = spec3(20);
        // Layer 1 (rx=1): stream of layer-0 worker rho has cols ≡ rho in
        // [1, 19); needed for layer-1 worker j tap t: [2+t-1, 18+t-1).
        let f = temporal_bits(&spec, 1, 1, 0, 0, 0);
        if let FilterSpec::Bits { m, n, p } = f {
            // Stream cols 1..19 (18 tokens); needed cols [1, 17): m=0 n=16 p=2.
            assert_eq!((m, n, p), (0, 16, 2));
        } else {
            panic!("expected bits");
        }
    }

    #[test]
    fn rejects_too_many_steps() {
        assert!(build(&spec3(8), 1, 5).is_err());
    }

    #[test]
    fn valid_range_shrinks_linearly() {
        let spec = spec3(100);
        assert_eq!(valid_range(&spec, 1), (1, 99));
        assert_eq!(valid_range(&spec, 10), (10, 90));
    }

    #[test]
    fn graph_validates_for_depths() {
        let spec = spec3(64);
        for steps in 1..=4 {
            let g = build(&spec, 2, steps).unwrap();
            assert!(crate::dfg::validate::check(&g).is_empty(), "steps={steps}");
        }
    }
}
