//! Allocation watchdog for the simulator's zero-allocation contract.
//!
//! The simulator cycle loops ([`crate::cgra::sim`]) promise **zero heap
//! allocations after warm-up**: every growable structure (channel token
//! arena, memory tickets, waiter lists, the event wheel) is sized at
//! build time. This module is how that promise is *tested* rather than
//! asserted in prose:
//!
//! * The cycle loops wrap themselves in [`enter_hot_region`] guards.
//! * `rust/tests/alloc_free.rs` installs a counting `#[global_allocator]`
//!   that forwards to the system allocator and calls [`note_alloc`] on
//!   every allocation.
//! * An allocation performed *by a thread inside a hot region* counts as
//!   a violation; the test asserts [`violations`]` == 0` over a warm
//!   `Session::run`.
//!
//! The region flag is thread-local, so pool workers simulating tiles are
//! watched while the session thread merging outputs (which legitimately
//! allocates) is not. When no counting allocator is installed (normal
//! builds, benches), the guards cost two TLS writes per simulation and
//! nothing else.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

thread_local! {
    static IN_HOT_REGION: Cell<bool> = const { Cell::new(false) };
}

static VIOLATIONS: AtomicU64 = AtomicU64::new(0);

/// Record one allocation. Called by a test-installed global allocator;
/// counts a violation iff the calling thread is inside a hot region.
/// Never panics (allocator context): TLS teardown reads as "not hot".
#[inline]
pub fn note_alloc() {
    if IN_HOT_REGION.try_with(Cell::get).unwrap_or(false) {
        VIOLATIONS.fetch_add(1, Ordering::Relaxed);
    }
}

/// Total allocations observed inside hot regions since the last [`reset`].
pub fn violations() -> u64 {
    VIOLATIONS.load(Ordering::Relaxed)
}

/// Zero the violation counter (test setup between warm-up and the
/// measured run).
pub fn reset() {
    VIOLATIONS.store(0, Ordering::Relaxed);
}

/// RAII guard marking the current thread as inside an allocation-free
/// hot region. Nesting is preserved (the previous flag is restored).
pub struct HotRegionGuard {
    prev: bool,
}

/// Enter a hot region on this thread; exits when the guard drops.
pub fn enter_hot_region() -> HotRegionGuard {
    let prev = IN_HOT_REGION.with(|c| c.replace(true));
    HotRegionGuard { prev }
}

impl Drop for HotRegionGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        let _ = IN_HOT_REGION.try_with(|c| c.set(prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_scopes_the_flag_and_counts() {
        reset();
        note_alloc(); // outside: ignored
        assert_eq!(violations(), 0);
        {
            let _g = enter_hot_region();
            note_alloc();
            note_alloc();
        }
        note_alloc(); // outside again
        assert!(violations() >= 2, "in-region allocs counted");
    }

    #[test]
    fn nested_guards_restore_outer_state() {
        let _outer = enter_hot_region();
        {
            let _inner = enter_hot_region();
        }
        // Still hot after the inner guard drops.
        let before = violations();
        note_alloc();
        assert_eq!(violations(), before + 1);
    }

    #[test]
    fn other_threads_are_not_hot() {
        reset();
        let _g = enter_hot_region();
        std::thread::spawn(|| {
            note_alloc(); // that thread never entered a region
        })
        .join()
        .unwrap();
        // Only allocations we note on *this* thread count.
        let before = violations();
        note_alloc();
        assert_eq!(violations(), before + 1);
    }
}
