//! Mini benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`run`] / [`BenchStats`]: fixed warmup, N timed iterations, and a
//! mean / median / stddev / min report on stdout. Deterministic
//! iteration counts keep bench output diff-able run to run.
//!
//! For trend tracking, [`JsonSink`] collects per-case records and
//! writes a machine-readable JSON array (e.g. `BENCH_sim.json`, which
//! CI uploads as an artifact so the perf trajectory in
//! `EXPERIMENTS.md` §Perf can be extended from any run).

use std::fmt::Write as _;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Throughput in "units per second" given units of work per iteration.
    pub fn per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

fn summarize(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        stddev_s: var.sqrt(),
        min_s: samples[0],
        max_s: samples[n - 1],
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let stats = summarize(name, samples);
    print_stats(&stats);
    stats
}

/// Print one row in the canonical bench format.
pub fn print_stats(s: &BenchStats) {
    println!(
        "bench {:<40} iters={:<3} mean={:>10.4} ms  median={:>10.4} ms  sd={:>8.4} ms  min={:>10.4} ms",
        s.name,
        s.iters,
        s.mean_s * 1e3,
        s.median_s * 1e3,
        s.stddev_s * 1e3,
        s.min_s * 1e3,
    );
}

/// Print a section header so bench output reads like the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A guard against the optimizer eliminating a computed value.
#[inline]
pub fn black_box<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// Collector for machine-readable bench records. No external JSON crate
/// is available offline, so records are assembled by hand; names/keys
/// are plain ASCII identifiers and values are finite numbers, which is
/// all the format needs.
#[derive(Debug, Default)]
pub struct JsonSink {
    records: Vec<String>,
}

impl JsonSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one record: the timing stats plus bench-specific numeric
    /// fields (cycles, throughput, ...).
    pub fn record(&mut self, stats: &BenchStats, extra: &[(&str, f64)]) {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"name\":\"{}\",\"iters\":{},\"mean_s\":{:.9},\"median_s\":{:.9},\"stddev_s\":{:.9},\"min_s\":{:.9}",
            json_escape(&stats.name),
            stats.iters,
            stats.mean_s,
            stats.median_s,
            stats.stddev_s,
            stats.min_s,
        );
        for (k, v) in extra {
            if v.is_finite() {
                let _ = write!(s, ",\"{}\":{v}", json_escape(k));
            }
        }
        s.push('}');
        self.records.push(s);
    }

    /// Number of records collected so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The full JSON document (an array of records).
    pub fn to_json(&self) -> String {
        format!("[\n  {}\n]\n", self.records.join(",\n  "))
    }

    /// Write the document to `path` and report where it went.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("\nwrote {} bench records to {path}", self.records.len());
        Ok(())
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            c if (c as u32) < 0x20 => vec![' '],
            c => vec![c],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = run("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.max_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn per_sec_scales() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            median_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert!((s.per_sec(100.0) - 200.0).abs() < 1e-12);
    }

    #[test]
    fn json_sink_emits_parseable_records() {
        let s = BenchStats {
            name: "case \"a\"".into(),
            iters: 3,
            mean_s: 0.25,
            median_s: 0.25,
            stddev_s: 0.0,
            min_s: 0.2,
            max_s: 0.3,
        };
        let mut sink = JsonSink::new();
        sink.record(&s, &[("cycles", 1234.0), ("nan_dropped", f64::NAN)]);
        assert_eq!(sink.len(), 1);
        let doc = sink.to_json();
        assert!(doc.starts_with("[\n"), "{doc}");
        assert!(doc.contains("\"name\":\"case \\\"a\\\"\""), "{doc}");
        assert!(doc.contains("\"cycles\":1234"), "{doc}");
        assert!(!doc.contains("nan_dropped"), "non-finite values dropped: {doc}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
    }
}
