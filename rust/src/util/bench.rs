//! Mini benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`run`] / [`BenchStats`]: fixed warmup, N timed iterations, and a
//! mean / median / stddev / min report on stdout. Deterministic
//! iteration counts keep bench output diff-able run to run.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Throughput in "units per second" given units of work per iteration.
    pub fn per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

fn summarize(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: samples[n / 2],
        stddev_s: var.sqrt(),
        min_s: samples[0],
        max_s: samples[n - 1],
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let stats = summarize(name, samples);
    print_stats(&stats);
    stats
}

/// Print one row in the canonical bench format.
pub fn print_stats(s: &BenchStats) {
    println!(
        "bench {:<40} iters={:<3} mean={:>10.4} ms  median={:>10.4} ms  sd={:>8.4} ms  min={:>10.4} ms",
        s.name,
        s.iters,
        s.mean_s * 1e3,
        s.median_s * 1e3,
        s.stddev_s * 1e3,
        s.min_s * 1e3,
    );
}

/// Print a section header so bench output reads like the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A guard against the optimizer eliminating a computed value.
#[inline]
pub fn black_box<T>(v: T) -> T {
    std::hint::black_box(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = run("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.max_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn per_sec_scales() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            median_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert!((s.per_sec(100.0) - 200.0).abs() < 1e-12);
    }
}
