//! Deterministic, seeded fault injection for the simulated machine.
//!
//! A [`FaultPlan`] describes three transient fault families:
//!
//! * **memory-line fill failures** — a fraction of DRAM fill grants
//!   fail and are retried by `MemSys` with bounded exponential backoff
//!   (retries counted in `MemStats::retries`);
//! * **channel/link stall windows** — pushes into a stalled channel
//!   take extra cycles to become visible downstream;
//! * **PE slow-down epochs** — a PE (placement slot) is suppressed
//!   from firing for whole epochs at a time.
//!
//! Every decision is a *pure function* of the seed and quantities both
//! scheduler cores compute bit-identically — the global fill-attempt
//! index, `(channel id, epoch)`, `(slot id, epoch)` — never of host
//! state, wall time, or evaluation order. That is what makes a faulted
//! run replayable: `dense == event` holds under any plan, and the same
//! plan + same input always produce the same cycle count, the same
//! retry count and the same output bits. The generator is
//! [`util::rng::XorShift`](super::rng::XorShift) used statelessly: one
//! fresh generator per decision, keyed by seed + salt + coordinates.
//!
//! An unarmed plan (all percentages zero, the default) must cost
//! nothing: every injection site branches on `armed()` once and the
//! hooks allocate nothing, so the fault-free hot path stays
//! allocation-free and bit-identical to a build without faults
//! (pinned by `tests/alloc_free.rs` and the `sim_hotpath` fault
//! section's zero-overhead gate).

use anyhow::{bail, Result};

use super::rng::XorShift;

/// Salts separating the three decision streams drawn from one seed.
const SALT_FILL: u64 = 0x66696C6C; // "fill"
const SALT_STALL: u64 = 0x7374616C; // "stal"
const SALT_SLOW: u64 = 0x736C6F77; // "slow"

/// First retry waits this many cycles; each further retry doubles it.
pub const BACKOFF_BASE_CYCLES: u64 = 8;
/// Backoff is capped here regardless of retry count.
pub const BACKOFF_CAP_CYCLES: u64 = 1024;
/// After this many failed attempts a fill succeeds unconditionally —
/// the model is *transient* faults, so forward progress is guaranteed.
///
/// The largest reachable backoff window,
/// `BACKOFF_BASE_CYCLES << (MAX_FILL_RETRIES - 1)` = 256 cycles, must
/// stay below the simulator's minimum deadlock quiet period (≥ 258
/// cycles, see `PlacedGraph::deadlock_quiet`): a pending retry keeps
/// the memory queue non-empty without making progress, and if the
/// silence outlasted the quiet period the dense core would misreport a
/// deadlock that the retry was about to break. Pinned by a unit test
/// below.
pub const MAX_FILL_RETRIES: u32 = 6;

/// Upper bound accepted for `extra=` in [`FaultPlan::parse`]. Stalled
/// visibility (`latency + extra`) must stay below the deadlock quiet
/// period for the same reason as the backoff bound above.
pub const MAX_STALL_EXTRA: u64 = 200;

/// A seeded, serializable fault-injection schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for every decision stream.
    pub seed: u64,
    /// Percentage (0–100) of fill grants that fail transiently.
    pub fill_fail_pct: u8,
    /// Percentage (0–100) of `(channel, epoch)` windows that stall.
    pub stall_pct: u8,
    /// Extra visibility latency, in cycles, inside a stall window.
    pub stall_extra: u64,
    /// Percentage (0–100) of `(PE slot, epoch)` windows suppressed.
    pub slow_pct: u8,
    /// Epoch length in cycles for stall/slow-down windows.
    pub epoch_cycles: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 1,
            fill_fail_pct: 0,
            stall_pct: 0,
            stall_extra: 8,
            slow_pct: 0,
            epoch_cycles: 256,
        }
    }
}

/// One independent uniform draw in `[0, 100)` keyed by coordinates.
fn pct_draw(seed: u64, salt: u64, a: u64, b: u64) -> u64 {
    let key = seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(salt)
        .wrapping_add(a.wrapping_mul(0xBF58476D1CE4E5B9))
        .wrapping_add(b.wrapping_mul(0x94D049BB133111EB));
    XorShift::new(key).next_u64() % 100
}

impl FaultPlan {
    /// True when any fault family is enabled. Every injection site
    /// branches on this exactly once per decision.
    #[inline]
    pub fn armed(&self) -> bool {
        self.fill_fail_pct > 0 || self.stall_pct > 0 || self.slow_pct > 0
    }

    /// Does the `attempt`-th fill grant (a global per-`MemSys`
    /// counter) fail? Pure in `(seed, attempt)`.
    #[inline]
    pub fn fill_fails(&self, attempt: u64) -> bool {
        self.fill_fail_pct > 0
            && pct_draw(self.seed, SALT_FILL, attempt, 0) < self.fill_fail_pct as u64
    }

    /// Extra visibility latency for a push into channel `chan` at
    /// cycle `now` (0 when the window is clean).
    #[inline]
    pub fn stall_extra_at(&self, chan: u32, now: u64) -> u64 {
        if self.stall_pct == 0 {
            return 0;
        }
        let epoch = now / self.epoch_cycles;
        if pct_draw(self.seed, SALT_STALL, chan as u64, epoch) < self.stall_pct as u64 {
            self.stall_extra
        } else {
            0
        }
    }

    /// Is PE slot `slot` suppressed from firing at cycle `now`?
    #[inline]
    pub fn pe_suppressed(&self, slot: u32, now: u64) -> bool {
        self.slow_pct > 0
            && pct_draw(self.seed, SALT_SLOW, slot as u64, now / self.epoch_cycles)
                < self.slow_pct as u64
    }

    /// First cycle after `now` at which a suppressed slot *may* run
    /// again (the next epoch boundary — the new epoch is re-checked
    /// there, so callers loop / re-arm).
    #[inline]
    pub fn pe_release(&self, now: u64) -> u64 {
        (now / self.epoch_cycles + 1) * self.epoch_cycles
    }

    /// Upper bound on [`Self::stall_extra_at`] — the event core grows
    /// its wheel horizon by this so stalled wakes never alias.
    #[inline]
    pub fn max_extra_latency(&self) -> u64 {
        if self.stall_pct > 0 {
            self.stall_extra
        } else {
            0
        }
    }

    /// Backoff delay before the `retry`-th re-attempt of a failed
    /// fill: exponential from [`BACKOFF_BASE_CYCLES`], capped at
    /// [`BACKOFF_CAP_CYCLES`].
    #[inline]
    pub fn backoff(retry: u32) -> u64 {
        (BACKOFF_BASE_CYCLES << retry.min(16)).min(BACKOFF_CAP_CYCLES)
    }

    /// Parse the `key=value` form used by `--fault` and the `[fault]`
    /// config section: `seed=7 fill=20 stall=10 extra=12 slow=5
    /// epoch=256` (any subset; unknown keys are errors).
    pub fn parse(s: &str) -> Result<Self> {
        let mut plan = Self::default();
        for tok in s.split_whitespace() {
            let Some((k, v)) = tok.split_once('=') else {
                bail!("fault spec token `{tok}`: expected key=value");
            };
            let n: u64 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("fault spec {k} = `{v}`: {e}"))?;
            let pct = |k: &str| -> Result<u8> {
                anyhow::ensure!(n <= 100, "fault spec {k} = {n}: percentage > 100");
                Ok(n as u8)
            };
            match k {
                "seed" => plan.seed = n,
                "fill" => plan.fill_fail_pct = pct(k)?,
                "stall" => plan.stall_pct = pct(k)?,
                "extra" => {
                    anyhow::ensure!(
                        n <= MAX_STALL_EXTRA,
                        "fault spec extra = {n}: must be <= {MAX_STALL_EXTRA}"
                    );
                    plan.stall_extra = n;
                }
                "slow" => plan.slow_pct = pct(k)?,
                "epoch" => {
                    anyhow::ensure!(n > 0, "fault spec epoch must be > 0");
                    plan.epoch_cycles = n;
                }
                other => bail!(
                    "fault spec: unknown key `{other}` \
                     (seed|fill|stall|extra|slow|epoch)"
                ),
            }
        }
        Ok(plan)
    }

    /// Render back to the `key=value` form [`Self::parse`] reads —
    /// artifact/config serialization round-trips through this.
    pub fn to_spec(&self) -> String {
        format!(
            "seed={} fill={} stall={} extra={} slow={} epoch={}",
            self.seed,
            self.fill_fail_pct,
            self.stall_pct,
            self.stall_extra,
            self.slow_pct,
            self.epoch_cycles
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_by_default_and_cheap_answers() {
        let p = FaultPlan::default();
        assert!(!p.armed());
        assert!(!p.fill_fails(0));
        assert_eq!(p.stall_extra_at(3, 1000), 0);
        assert!(!p.pe_suppressed(5, 1000));
    }

    #[test]
    fn decisions_are_pure_functions_of_coordinates() {
        let p = FaultPlan {
            seed: 42,
            fill_fail_pct: 30,
            stall_pct: 25,
            slow_pct: 20,
            ..FaultPlan::default()
        };
        for i in 0..200 {
            assert_eq!(p.fill_fails(i), p.fill_fails(i));
            assert_eq!(p.stall_extra_at(3, i * 17), p.stall_extra_at(3, i * 17));
            assert_eq!(p.pe_suppressed(9, i * 31), p.pe_suppressed(9, i * 31));
        }
        // A different seed gives a different schedule somewhere.
        let q = FaultPlan { seed: 43, ..p.clone() };
        assert!((0..500).any(|i| p.fill_fails(i) != q.fill_fails(i)));
    }

    #[test]
    fn fill_failure_rate_tracks_the_percentage() {
        let p = FaultPlan { seed: 7, fill_fail_pct: 25, ..FaultPlan::default() };
        let n = 20_000u64;
        let fails = (0..n).filter(|&i| p.fill_fails(i)).count() as f64;
        let rate = fails / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn stall_windows_are_epoch_granular() {
        let p = FaultPlan {
            seed: 11,
            stall_pct: 50,
            stall_extra: 12,
            epoch_cycles: 256,
            ..FaultPlan::default()
        };
        // Within one epoch the answer is constant.
        for c in 0..64u32 {
            let e0 = p.stall_extra_at(c, 512);
            for t in 512..768 {
                assert_eq!(p.stall_extra_at(c, t), e0);
            }
        }
        assert_eq!(p.max_extra_latency(), 12);
        assert_eq!(FaultPlan::default().max_extra_latency(), 0);
    }

    #[test]
    fn release_is_the_next_epoch_boundary() {
        let p = FaultPlan { epoch_cycles: 256, ..FaultPlan::default() };
        assert_eq!(p.pe_release(0), 256);
        assert_eq!(p.pe_release(255), 256);
        assert_eq!(p.pe_release(256), 512);
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        assert_eq!(FaultPlan::backoff(0), 8);
        assert_eq!(FaultPlan::backoff(1), 16);
        assert_eq!(FaultPlan::backoff(2), 32);
        assert_eq!(FaultPlan::backoff(40), BACKOFF_CAP_CYCLES);
    }

    #[test]
    fn reachable_backoff_stays_below_the_minimum_deadlock_quiet_period() {
        // See the MAX_FILL_RETRIES docs: the deepest reachable backoff
        // window must be shorter than the smallest possible quiet
        // period (dram_latency >= 1, max channel latency >= 1, + 256).
        let deepest = (0..MAX_FILL_RETRIES).map(FaultPlan::backoff).max().unwrap();
        assert!(deepest < 258, "deepest backoff {deepest} >= min quiet period");
    }

    #[test]
    fn spec_round_trips() {
        let p = FaultPlan::parse("seed=9 fill=20 stall=10 extra=4 slow=5 epoch=128").unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.fill_fail_pct, 20);
        assert_eq!(p.stall_pct, 10);
        assert_eq!(p.stall_extra, 4);
        assert_eq!(p.slow_pct, 5);
        assert_eq!(p.epoch_cycles, 128);
        assert_eq!(FaultPlan::parse(&p.to_spec()).unwrap(), p);
    }

    #[test]
    fn spec_rejects_bad_input() {
        assert!(FaultPlan::parse("fill").is_err());
        assert!(FaultPlan::parse("fill=abc").is_err());
        assert!(FaultPlan::parse("fill=120").is_err());
        assert!(FaultPlan::parse("warp=1").is_err());
        assert!(FaultPlan::parse("epoch=0").is_err());
    }
}
