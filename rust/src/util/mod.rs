//! Small self-contained utilities: a deterministic PRNG for
//! property-style tests, a mini benchmark harness (criterion is not
//! available in the offline vendor set), the simulator's
//! allocation watchdog, deterministic run traces, seeded fault
//! injection, and timing helpers.

pub mod allocwatch;
pub mod bench;
pub mod fault;
pub mod rng;
pub mod trace;

/// Ceiling division for unsigned sizes.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a float with engineering-style thousands grouping for tables.
pub fn fmt_f64(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(0, 8), 0);
    }
}
