//! Deterministic xorshift* PRNG.
//!
//! The offline vendor set has no `rand`/`proptest`, so property-style
//! tests and workload generators use this small, seedable generator.
//! Sequences are stable across platforms and runs.

/// xorshift64* generator. Never yields the all-zero state.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create a generator from a seed (0 is remapped to a fixed constant).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift (Lemire); bias is negligible for test workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard-normal-ish value via the sum of 12 uniforms (Irwin–Hall).
    /// Good enough for numeric test payloads; cheap and branch-free.
    pub fn normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.f64();
        }
        s - 6.0
    }

    /// Fill a vector with `n` pseudo-normal f64 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = XorShift::new(7);
        for _ in 0..1000 {
            let v = g.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut g = XorShift::new(9);
        for _ in 0..1000 {
            assert!(g.below(17) < 17);
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut g = XorShift::new(1234);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut g = XorShift::new(0);
        assert_ne!(g.next_u64(), 0);
    }
}
