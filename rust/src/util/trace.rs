//! Deterministic run traces: record the per-tile simulation fingerprint
//! of a `scgra run`, then replay a later run against it and fail loudly
//! on the first divergence.
//!
//! The simulator is deterministic by construction (see `cgra/sim.rs`),
//! so a perf rework that accidentally changes *behaviour* — one extra
//! fire, one reordered memory grant — shows up as a different cycle
//! count, fire count, ticket count, fire-sequence hash or output hash
//! for some tile task. A trace is one [`TraceRecord`] per executed tile
//! task (fused phase plus each boundary-ring band), keyed by
//! `(chunk, phase, task)` in deterministic task order.
//!
//! [`Trace::matches`] deliberately ignores `wakeups`: that counter is
//! core-dependent bookkeeping (always 0 under the dense core), so a
//! trace recorded under `--sim-core dense` replays cleanly under
//! `--sim-core event` — the cross-core differential in CI rides on
//! exactly this property.
//!
//! The on-disk format is a versioned plain-text table (one line per
//! record) so diffs are reviewable and no serde dependency is needed.

use anyhow::{anyhow, bail, ensure, Context, Result};

/// What `--trace <mode> <path>` asked for.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceMode {
    /// Run normally and write the trace to the path.
    Record(String),
    /// Run normally, load the trace from the path, and fail on mismatch.
    Replay(String),
}

impl TraceMode {
    /// Parse the CLI/config form: `record PATH` / `replay PATH`
    /// (a `mode:PATH` colon form is accepted too).
    pub fn parse(s: &str) -> Result<TraceMode> {
        let s = s.trim();
        let (mode, path) = s
            .split_once(char::is_whitespace)
            .or_else(|| s.split_once(':'))
            .ok_or_else(|| {
                anyhow!("expected `record PATH` or `replay PATH`, got `{s}`")
            })?;
        let path = path.trim().to_string();
        ensure!(!path.is_empty(), "trace path is empty in `{s}`");
        match mode {
            "record" => Ok(TraceMode::Record(path)),
            "replay" => Ok(TraceMode::Replay(path)),
            other => bail!("unknown trace mode `{other}` (record|replay)"),
        }
    }
}

/// Fingerprint of one executed tile task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceRecord {
    /// Host-schedule chunk index.
    pub chunk: u32,
    /// 0 = fused interior phase; 1.. = boundary-ring bands.
    pub phase: u32,
    /// Task index within the phase (deterministic task order).
    pub task: u32,
    /// Simulated cycles for this task.
    pub cycles: u64,
    /// Total instruction fires.
    pub fires: u64,
    /// Memory tickets issued (loads + stores).
    pub tickets: u64,
    /// Order-sensitive hash of the (node, cycle) fire sequence.
    pub fire_hash: u64,
    /// FNV-1a hash of the task's output grid bit patterns.
    pub output_hash: u64,
    /// Event-core wakeups (0 under dense) — recorded for inspection,
    /// ignored by [`Trace::matches`].
    pub wakeups: u64,
}

/// A recorded run: one record per executed tile task.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

const HEADER: &str = "scgra-trace v1";

impl Trace {
    /// Serialize to the versioned text format.
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(32 + self.records.len() * 96);
        out.push_str(HEADER);
        out.push('\n');
        for r in &self.records {
            out.push_str(&format!(
                "{} {} {} {} {} {} {:016x} {:016x} {}\n",
                r.chunk,
                r.phase,
                r.task,
                r.cycles,
                r.fires,
                r.tickets,
                r.fire_hash,
                r.output_hash,
                r.wakeups
            ));
        }
        out
    }

    /// Parse the text format produced by [`Trace::to_text`].
    pub fn parse(text: &str) -> Result<Trace> {
        let mut lines = text.lines();
        let head = lines.next().unwrap_or("").trim();
        ensure!(
            head == HEADER,
            "not a trace file: expected `{HEADER}` header, got `{head}`"
        );
        let mut records = Vec::new();
        for (i, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            ensure!(
                f.len() == 9,
                "trace line {}: expected 9 fields, got {}",
                i + 2,
                f.len()
            );
            let dec = |s: &str, what: &str| -> Result<u64> {
                s.parse::<u64>()
                    .map_err(|_| anyhow!("trace line {}: bad {what} `{s}`", i + 2))
            };
            let hex = |s: &str, what: &str| -> Result<u64> {
                u64::from_str_radix(s, 16)
                    .map_err(|_| anyhow!("trace line {}: bad {what} `{s}`", i + 2))
            };
            records.push(TraceRecord {
                chunk: dec(f[0], "chunk")? as u32,
                phase: dec(f[1], "phase")? as u32,
                task: dec(f[2], "task")? as u32,
                cycles: dec(f[3], "cycles")?,
                fires: dec(f[4], "fires")?,
                tickets: dec(f[5], "tickets")?,
                fire_hash: hex(f[6], "fire_hash")?,
                output_hash: hex(f[7], "output_hash")?,
                wakeups: dec(f[8], "wakeups")?,
            });
        }
        Ok(Trace { records })
    }

    /// Write to `path` in text form.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing trace to {path}"))
    }

    /// Load from `path`.
    pub fn load(path: &str) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace from {path}"))?;
        Trace::parse(&text).with_context(|| format!("parsing trace {path}"))
    }

    /// Compare a fresh run (`self`) against a recorded `reference`.
    /// Everything except `wakeups` must match record-for-record;
    /// reports the first divergence with both values.
    pub fn matches(&self, reference: &Trace) -> Result<()> {
        ensure!(
            self.records.len() == reference.records.len(),
            "trace length mismatch: run has {} tile tasks, recording has {}",
            self.records.len(),
            reference.records.len()
        );
        for (got, want) in self.records.iter().zip(&reference.records) {
            let key = format!(
                "chunk {} phase {} task {}",
                want.chunk, want.phase, want.task
            );
            ensure!(
                (got.chunk, got.phase, got.task) == (want.chunk, want.phase, want.task),
                "trace task order diverged at {key}: run has chunk {} phase {} task {}",
                got.chunk,
                got.phase,
                got.task
            );
            let diff = |name: &str, g: u64, w: u64| -> Result<()> {
                ensure!(g == w, "trace mismatch at {key}: {name} {g} != recorded {w}");
                Ok(())
            };
            diff("cycles", got.cycles, want.cycles)?;
            diff("fires", got.fires, want.fires)?;
            diff("tickets", got.tickets, want.tickets)?;
            ensure!(
                got.fire_hash == want.fire_hash,
                "trace mismatch at {key}: fire_hash {:016x} != recorded {:016x}",
                got.fire_hash,
                want.fire_hash
            );
            ensure!(
                got.output_hash == want.output_hash,
                "trace mismatch at {key}: output_hash {:016x} != recorded {:016x}",
                got.output_hash,
                want.output_hash
            );
            // wakeups intentionally not compared: core-dependent.
        }
        Ok(())
    }
}

/// FNV-1a over the bit patterns of a float slice — the output fingerprint
/// stored per trace record. Bitwise, so `-0.0 != 0.0` and NaN payloads
/// count: exactly the identity the cross-core tests pin.
pub fn hash_f64s(xs: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            records: vec![
                TraceRecord {
                    chunk: 0,
                    phase: 0,
                    task: 0,
                    cycles: 1234,
                    fires: 999,
                    tickets: 48,
                    fire_hash: 0xdeadbeefcafe,
                    output_hash: 0x12345678,
                    wakeups: 777,
                },
                TraceRecord {
                    chunk: 0,
                    phase: 1,
                    task: 2,
                    cycles: 88,
                    fires: 12,
                    tickets: 4,
                    fire_hash: 1,
                    output_hash: 2,
                    wakeups: 0,
                },
            ],
        }
    }

    #[test]
    fn text_roundtrip_is_lossless() {
        let t = sample();
        let back = Trace::parse(&t.to_text()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::parse("not a trace").is_err());
        assert!(Trace::parse("scgra-trace v1\n1 2 3\n").is_err());
        assert!(Trace::parse("scgra-trace v1\n1 2 3 4 5 6 zz 0 0\n").is_err());
    }

    #[test]
    fn matches_ignores_wakeups_but_pins_everything_else() {
        let t = sample();
        let mut other = t.clone();
        other.records[0].wakeups = 0; // dense-core replay of an event trace
        t.matches(&other).unwrap();
        other.records[1].cycles += 1;
        let err = t.matches(&other).unwrap_err().to_string();
        assert!(err.contains("cycles"), "{err}");
        assert!(err.contains("chunk 0 phase 1 task 2"), "{err}");
    }

    #[test]
    fn matches_detects_length_and_hash_divergence() {
        let t = sample();
        let mut short = t.clone();
        short.records.pop();
        assert!(t.matches(&short).is_err());
        let mut tampered = t.clone();
        tampered.records[0].output_hash ^= 1;
        let err = t.matches(&tampered).unwrap_err().to_string();
        assert!(err.contains("output_hash"), "{err}");
    }

    #[test]
    fn trace_mode_parses_both_forms() {
        assert_eq!(
            TraceMode::parse("record /tmp/t.trace").unwrap(),
            TraceMode::Record("/tmp/t.trace".into())
        );
        assert_eq!(
            TraceMode::parse("replay:out.trace").unwrap(),
            TraceMode::Replay("out.trace".into())
        );
        assert!(TraceMode::parse("record").is_err());
        assert!(TraceMode::parse("verify x").is_err());
    }

    #[test]
    fn hash_is_bitwise() {
        assert_ne!(hash_f64s(&[0.0]), hash_f64s(&[-0.0]));
        assert_eq!(hash_f64s(&[1.5, 2.5]), hash_f64s(&[1.5, 2.5]));
        assert_ne!(hash_f64s(&[1.5, 2.5]), hash_f64s(&[2.5, 1.5]));
    }
}
