//! Native oracles + one-call simulation helpers.
//!
//! The oracles accumulate in exactly the chain order of §III (x taps
//! left-to-right, then y taps `-ry..-1, +1..+ry`), matching `ref.py` and
//! the Pallas kernels, so all three layers agree to ~1e-12 in f64.

use anyhow::Result;

use crate::cgra::{Machine, SimResult, Simulator};
use crate::stencil::{map1d, map2d, StencilSpec};

/// 1-D star stencil, interior computed, boundary copied.
pub fn stencil1d_ref(x: &[f64], coeffs: &[f64]) -> Vec<f64> {
    let r = (coeffs.len() - 1) / 2;
    let mut out = x.to_vec();
    for o in r..x.len() - r {
        let mut acc = coeffs[0] * x[o - r];
        for (k, &ck) in coeffs.iter().enumerate().skip(1) {
            acc += ck * x[o - r + k];
        }
        out[o] = acc;
    }
    out
}

/// 2-D star stencil over a row-major `nx * ny` grid.
pub fn stencil2d_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    let (nx, ny, rx, ry) = (spec.nx, spec.ny, spec.rx, spec.ry);
    let mut out = x.to_vec();
    for r in ry..ny - ry {
        for c in rx..nx - rx {
            let mut acc = spec.cx[0] * x[r * nx + c - rx];
            for t in 1..2 * rx + 1 {
                acc += spec.cx[t] * x[r * nx + c - rx + t];
            }
            for (u, &cu) in spec.cy.iter().enumerate() {
                let k = if u < ry { u } else { u + 1 };
                acc += cu * x[(r + k - ry) * nx + c];
            }
            out[r * nx + c] = acc;
        }
    }
    out
}

/// One 5-point Jacobi heat step (`alpha`-weighted), boundary fixed.
pub fn heat2d_step_ref(x: &[f64], nx: usize, ny: usize, alpha: f64) -> Vec<f64> {
    let spec = StencilSpec::heat2d(nx, ny, alpha);
    stencil2d_ref(x, &spec)
}

/// Map `spec` with `w` workers, simulate on `m`, return the result.
/// The output buffer starts as a copy of the input, so boundary points
/// carry the input values (the Dirichlet contract all layers share).
pub fn run_sim(spec: &StencilSpec, w: usize, m: &Machine, input: &[f64]) -> Result<SimResult> {
    let g = if spec.is_1d() {
        map1d::build(spec, w)?
    } else {
        map2d::build(spec, w)?
    };
    Simulator::build(g, m, input.to_vec(), input.to_vec())?.run()
}

/// Maximum absolute elementwise difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn sim_matches_oracle_1d_property() {
        let mut rng = XorShift::new(0xABCD);
        let m = Machine::paper();
        for _case in 0..6 {
            let r = rng.range(1, 4);
            let nx = rng.range(2 * r + 2, 120);
            let w = rng.range(1, 5);
            let coeffs: Vec<f64> = (0..2 * r + 1).map(|_| rng.normal()).collect();
            let spec = StencilSpec::dim1(nx, coeffs).unwrap();
            let x = rng.normal_vec(nx);
            let res = run_sim(&spec, w, &m, &x).unwrap();
            let want = stencil1d_ref(&x, &spec.cx);
            assert!(
                max_abs_diff(&res.output, &want) < 1e-11,
                "nx={nx} r={r} w={w}"
            );
        }
    }

    #[test]
    fn sim_matches_oracle_2d_property() {
        let mut rng = XorShift::new(0x5EED);
        let m = Machine::paper();
        for _case in 0..4 {
            let rx = rng.range(1, 3);
            let ry = rng.range(1, 3);
            let nx = rng.range(2 * rx + 2, 36);
            let ny = rng.range(2 * ry + 2, 28);
            let w = rng.range(1, 4);
            let cx: Vec<f64> = (0..2 * rx + 1).map(|_| rng.normal()).collect();
            let cy: Vec<f64> = (0..2 * ry).map(|_| rng.normal()).collect();
            let spec = StencilSpec::dim2(nx, ny, cx, cy).unwrap();
            let x = rng.normal_vec(nx * ny);
            let res = run_sim(&spec, w, &m, &x).unwrap();
            let want = stencil2d_ref(&x, &spec);
            assert!(
                max_abs_diff(&res.output, &want) < 1e-11,
                "nx={nx} ny={ny} rx={rx} ry={ry} w={w}"
            );
        }
    }

    #[test]
    fn heat_ref_conserves_uniform_field() {
        let x = vec![2.5; 12 * 12];
        let out = heat2d_step_ref(&x, 12, 12, 0.2);
        assert!(max_abs_diff(&x, &out) < 1e-12);
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
