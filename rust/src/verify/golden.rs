//! Native oracles + one-call simulation helpers.
//!
//! The oracles accumulate in exactly the chain order of §III (for a star:
//! x taps left-to-right, then y taps `-ry..-1, +1..+ry`, then z taps
//! likewise; for a box: z-major over the dense window), matching
//! `ref.py`, the Pallas kernels and [`StencilSpec::chain_taps`], so all
//! layers agree to ~1e-12 in f64. [`stencil_ref`] is the shape-generic
//! oracle; the dimension-specific functions are thin fronts kept for the
//! original 1-D/2-D call sites.

use anyhow::Result;

use crate::cgra::{Machine, SimCore, SimResult, Simulator};
use crate::stencil::{build_graph, StencilSpec};

/// 1-D star stencil, interior computed, boundary copied.
pub fn stencil1d_ref(x: &[f64], coeffs: &[f64]) -> Vec<f64> {
    let r = (coeffs.len() - 1) / 2;
    let mut out = x.to_vec();
    for o in r..x.len() - r {
        let mut acc = coeffs[0] * x[o - r];
        for (k, &ck) in coeffs.iter().enumerate().skip(1) {
            acc += ck * x[o - r + k];
        }
        out[o] = acc;
    }
    out
}

/// 2-D star stencil over a row-major `nx * ny` grid.
pub fn stencil2d_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    let (nx, ny, rx, ry) = (spec.nx, spec.ny, spec.rx, spec.ry);
    let mut out = x.to_vec();
    for r in ry..ny - ry {
        for c in rx..nx - rx {
            let mut acc = spec.cx[0] * x[r * nx + c - rx];
            for t in 1..2 * rx + 1 {
                acc += spec.cx[t] * x[r * nx + c - rx + t];
            }
            for (u, &cu) in spec.cy.iter().enumerate() {
                let k = if u < ry { u } else { u + 1 };
                acc += cu * x[(r + k - ry) * nx + c];
            }
            out[r * nx + c] = acc;
        }
    }
    out
}

/// One 5-point Jacobi heat step (`alpha`-weighted), boundary fixed.
pub fn heat2d_step_ref(x: &[f64], nx: usize, ny: usize, alpha: f64) -> Vec<f64> {
    let spec = StencilSpec::heat2d(nx, ny, alpha);
    stencil2d_ref(x, &spec)
}

/// Shape-generic reference: any star or box spec in 1, 2 or 3
/// dimensions, accumulated in [`StencilSpec::chain_taps`] order (the
/// exact f64 association order of the mapped MAC chain, so simulator and
/// oracle agree bitwise). Interior computed, boundary copied.
pub fn stencil_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    assert_eq!(x.len(), spec.grid_points());
    let (nx, ny, nz) = (spec.nx, spec.ny, spec.nz);
    let (rx, ry, rz) = (spec.rx, spec.ry, spec.rz);
    let taps = spec.chain_taps();
    let mut out = x.to_vec();
    for z in rz..nz - rz {
        for y in ry..ny - ry {
            for c in rx..nx - rx {
                let mut acc = 0.0;
                for (k, &(dz, dy, dx, co)) in taps.iter().enumerate() {
                    let zz = (z as i64 + dz) as usize;
                    let yy = (y as i64 + dy) as usize;
                    let cc = (c as i64 + dx) as usize;
                    let v = co * x[(zz * ny + yy) * nx + cc];
                    if k == 0 {
                        acc = v;
                    } else {
                        acc += v;
                    }
                }
                out[(z * ny + y) * nx + c] = acc;
            }
        }
    }
    out
}

/// The iterated golden oracle: `steps` applications of [`stencil_ref`]
/// (interior computed, boundary copied each step) — the §IV reference
/// every temporal-fusion path is compared against. The fused pipeline
/// must equal it *bitwise* on the valid trapezoid box
/// [`crate::stencil::temporal::valid_box`]`(spec, steps)`.
pub fn stencil_ref_steps(spec: &StencilSpec, input: &[f64], steps: usize) -> Vec<f64> {
    let mut grid = input.to_vec();
    for _ in 0..steps {
        grid = stencil_ref(&grid, spec);
    }
    grid
}

/// 3-D star stencil over a row-major `nx * ny * nz` volume.
pub fn stencil3d_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    assert!(spec.is_3d() && !spec.is_box());
    stencil_ref(x, spec)
}

/// 2-D box (dense-window) stencil.
pub fn box2d_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    assert!(spec.is_2d() && spec.is_box());
    stencil_ref(x, spec)
}

/// 3-D box (dense-window) stencil.
pub fn box3d_ref(x: &[f64], spec: &StencilSpec) -> Vec<f64> {
    assert!(spec.is_3d() && spec.is_box());
    stencil_ref(x, spec)
}

/// Map `spec` with `w` workers, simulate on `m` with an explicit
/// scheduler core, return the result. The output buffer starts as a
/// copy of the input, so boundary points carry the input values (the
/// Dirichlet contract all layers share). Dispatches across all
/// supported shapes via [`crate::stencil::build_graph`].
pub fn run_sim_core(
    spec: &StencilSpec,
    w: usize,
    m: &Machine,
    input: &[f64],
    core: SimCore,
) -> Result<SimResult> {
    let g = build_graph(spec, w)?;
    Simulator::build(g, m, input.to_vec(), input.to_vec())?
        .with_core(core)
        .run()
}

/// [`run_sim_core`] with the default (event-driven) core.
pub fn run_sim(spec: &StencilSpec, w: usize, m: &Machine, input: &[f64]) -> Result<SimResult> {
    run_sim_core(spec, w, m, input, SimCore::default())
}

/// Maximum absolute elementwise difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn sim_matches_oracle_1d_property() {
        let mut rng = XorShift::new(0xABCD);
        let m = Machine::paper();
        for _case in 0..6 {
            let r = rng.range(1, 4);
            let nx = rng.range(2 * r + 2, 120);
            let w = rng.range(1, 5);
            let coeffs: Vec<f64> = (0..2 * r + 1).map(|_| rng.normal()).collect();
            let spec = StencilSpec::dim1(nx, coeffs).unwrap();
            let x = rng.normal_vec(nx);
            let res = run_sim(&spec, w, &m, &x).unwrap();
            let want = stencil1d_ref(&x, &spec.cx);
            assert!(
                max_abs_diff(&res.output, &want) < 1e-11,
                "nx={nx} r={r} w={w}"
            );
        }
    }

    #[test]
    fn sim_matches_oracle_2d_property() {
        let mut rng = XorShift::new(0x5EED);
        let m = Machine::paper();
        for _case in 0..4 {
            let rx = rng.range(1, 3);
            let ry = rng.range(1, 3);
            let nx = rng.range(2 * rx + 2, 36);
            let ny = rng.range(2 * ry + 2, 28);
            let w = rng.range(1, 4);
            let cx: Vec<f64> = (0..2 * rx + 1).map(|_| rng.normal()).collect();
            let cy: Vec<f64> = (0..2 * ry).map(|_| rng.normal()).collect();
            let spec = StencilSpec::dim2(nx, ny, cx, cy).unwrap();
            let x = rng.normal_vec(nx * ny);
            let res = run_sim(&spec, w, &m, &x).unwrap();
            let want = stencil2d_ref(&x, &spec);
            assert!(
                max_abs_diff(&res.output, &want) < 1e-11,
                "nx={nx} ny={ny} rx={rx} ry={ry} w={w}"
            );
        }
    }

    #[test]
    fn heat_ref_conserves_uniform_field() {
        let x = vec![2.5; 12 * 12];
        let out = heat2d_step_ref(&x, 12, 12, 0.2);
        assert!(max_abs_diff(&x, &out) < 1e-12);
    }

    #[test]
    fn generic_ref_matches_legacy_1d_and_2d_bitwise() {
        let mut rng = XorShift::new(0x6E6E);
        let s1 = StencilSpec::dim1(40, crate::stencil::spec::symmetric_taps(3)).unwrap();
        let x1 = rng.normal_vec(40);
        assert_eq!(stencil_ref(&x1, &s1), stencil1d_ref(&x1, &s1.cx));

        let s2 = StencilSpec::dim2(
            18,
            14,
            crate::stencil::spec::symmetric_taps(2),
            crate::stencil::spec::y_taps(2),
        )
        .unwrap();
        let x2 = rng.normal_vec(18 * 14);
        assert_eq!(stencil_ref(&x2, &s2), stencil2d_ref(&x2, &s2));
    }

    #[test]
    fn heat3d_uniform_field_conserved() {
        let spec = StencilSpec::heat3d(8, 7, 6, 0.1);
        let x = vec![3.25; 8 * 7 * 6];
        let out = stencil3d_ref(&x, &spec);
        assert!(max_abs_diff(&x, &out) < 1e-12);
    }

    #[test]
    fn box_ref_uniform_window_is_local_mean() {
        // A normalized 3x3 box over a linear ramp reproduces the ramp.
        let spec = StencilSpec::box2d(
            10,
            6,
            1,
            1,
            crate::stencil::spec::uniform_box_taps(1, 1, 0),
        )
        .unwrap();
        let x: Vec<f64> = (0..60).map(|i| (i % 10) as f64).collect();
        let out = box2d_ref(&x, &spec);
        for r in 1..5 {
            for c in 1..9 {
                assert!((out[r * 10 + c] - c as f64).abs() < 1e-12, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn sim_matches_oracle_3d_star_and_box() {
        let m = Machine::paper();
        let mut rng = XorShift::new(0x3D5);
        let star = StencilSpec::heat3d(9, 7, 5, 0.1);
        let x = rng.normal_vec(9 * 7 * 5);
        let res = run_sim(&star, 2, &m, &x).unwrap();
        assert!(max_abs_diff(&res.output, &stencil3d_ref(&x, &star)) < 1e-11);

        let bx = StencilSpec::box3d(
            8,
            6,
            5,
            1,
            1,
            1,
            crate::stencil::spec::uniform_box_taps(1, 1, 1),
        )
        .unwrap();
        let xb = rng.normal_vec(8 * 6 * 5);
        let res = run_sim(&bx, 2, &m, &xb).unwrap();
        assert!(max_abs_diff(&res.output, &box3d_ref(&xb, &bx)) < 1e-11);
    }

    #[test]
    fn ref_steps_iterates_the_single_step_oracle() {
        let spec = StencilSpec::heat2d(10, 8, 0.2);
        let mut rng = XorShift::new(0x57E9);
        let x = rng.normal_vec(80);
        let once = stencil_ref_steps(&spec, &x, 1);
        assert_eq!(once, stencil_ref(&x, &spec));
        let thrice = stencil_ref_steps(&spec, &x, 3);
        assert_eq!(thrice, stencil_ref(&stencil_ref(&once, &spec), &spec));
        assert_eq!(stencil_ref_steps(&spec, &x, 0), x);
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
