//! Golden verification: the CGRA simulator's output is checked against a
//! native Rust oracle (same MAC-chain association order as the paper's
//! hardware) and — in the integration tests and the `e2e_validation`
//! example — against the PJRT-executed JAX/Pallas artifact, closing the
//! loop across all three layers.

pub mod golden;

pub use golden::{
    box2d_ref, box3d_ref, heat2d_step_ref, max_abs_diff, run_sim, run_sim_core,
    stencil1d_ref, stencil2d_ref, stencil3d_ref, stencil_ref,
};
