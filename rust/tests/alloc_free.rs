//! The zero-allocation contract, enforced: a counting global allocator
//! forwards every allocation to the system allocator and reports it to
//! `util::allocwatch`, which counts it as a violation iff the calling
//! thread is inside a simulator cycle loop (the hot region the cores
//! enter around their scheduling loops). A warm `Session::run` must
//! perform **zero** heap allocations there — every growable structure
//! (token arena, SoA node state, memory tickets, intrusive waiter
//! lists, the event wheel) is sized before the loop starts.
//!
//! The hot-region flag is thread-local, so the persistent pool's tile
//! workers are watched while the session thread stitching outputs
//! (which legitimately allocates) is not. Covered matrix: both
//! scheduler cores x star/box x 1/2/3-D, pooled and sequential.
//!
//! Tests in this binary share one global violation counter, so they
//! serialize on a mutex — a violation must be attributed to the run
//! that caused it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::{Arc, Mutex};

use stencil_cgra::cgra::SimCore;
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::session::{ExecMode, Session};
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::allocwatch;

struct CountingAlloc;

// SAFETY: forwards verbatim to `System`; `note_alloc` is documented
// allocator-safe (no allocation, no panic).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        allocwatch::note_alloc();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        allocwatch::note_alloc();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        allocwatch::note_alloc();
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

static LOCK: Mutex<()> = Mutex::new(());

/// Warm-up run, reset the counter, run again, assert the cycle loops
/// stayed allocation-free and the two runs agree bitwise.
fn assert_zero_alloc(name: &str, spec: &StencilSpec, core: SimCore, tiles: usize, exec: ExecMode) {
    // A failed assert poisons the lock; later cases should still run.
    let _serial = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let opts = CompileOptions::default().with_workers(2).with_tiles(tiles);
    let compiled = Arc::new(compile(spec, 1, &opts).unwrap());
    let machine = compiled.options.machine.clone();
    let session = Session::new(compiled, machine)
        .with_sim_core(core)
        .with_exec(exec);
    let x = vec![1.0; spec.grid_points()];

    let cold = session.run(&x).unwrap();
    allocwatch::reset();
    let warm = session.run(&x).unwrap();
    assert_eq!(
        allocwatch::violations(),
        0,
        "{name}/{core}: warm cycle loop allocated"
    );
    assert_eq!(warm.output, cold.output, "{name}/{core}: runs diverged");
}

fn all_cores(name: &str, spec: &StencilSpec, tiles: usize, exec: ExecMode) {
    assert_zero_alloc(name, spec, SimCore::Dense, tiles, exec);
    assert_zero_alloc(name, spec, SimCore::Event, tiles, exec);
}

#[test]
fn star_1d_is_alloc_free_warm() {
    let spec = StencilSpec::dim1(96, symmetric_taps(2)).unwrap();
    all_cores("star1d", &spec, 1, ExecMode::Pooled);
}

#[test]
fn star_2d_is_alloc_free_warm_pooled_two_tiles() {
    // Two tiles through the persistent pool: the per-thread hot-region
    // flag watches each worker's cycle loop independently.
    let spec = StencilSpec::dim2(24, 16, symmetric_taps(1), y_taps(1)).unwrap();
    all_cores("star2d", &spec, 2, ExecMode::Pooled);
}

#[test]
fn star_3d_is_alloc_free_warm() {
    let spec =
        StencilSpec::dim3(12, 8, 6, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap();
    all_cores("star3d", &spec, 1, ExecMode::Pooled);
}

#[test]
fn box_2d_is_alloc_free_warm() {
    let spec = StencilSpec::box2d(20, 12, 1, 1, uniform_box_taps(1, 1, 0)).unwrap();
    all_cores("box2d", &spec, 1, ExecMode::Pooled);
}

#[test]
fn box_3d_is_alloc_free_warm_sequential() {
    // Sequential mode runs the cycle loop on the session thread itself;
    // the contract must hold there exactly as on pool workers.
    let spec = StencilSpec::box3d(10, 8, 6, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
    all_cores("box3d", &spec, 1, ExecMode::Sequential);
}

#[test]
fn sequential_2d_is_alloc_free_warm() {
    let spec = StencilSpec::dim2(24, 16, symmetric_taps(1), y_taps(1)).unwrap();
    all_cores("star2d_seq", &spec, 2, ExecMode::Sequential);
}

#[test]
fn armed_fault_plan_keeps_the_cycle_loop_alloc_free() {
    // Injection decisions are stateless hashes, retries re-use the
    // reserved transaction queue, and stall/slow-down wakeups land in
    // the pre-sized wheel — so even a heavily faulted run must stay
    // allocation-free in the cycle loops.
    use stencil_cgra::FaultPlan;
    let _serial = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let spec = StencilSpec::dim2(24, 16, symmetric_taps(1), y_taps(1)).unwrap();
    let opts = CompileOptions::default().with_workers(2).with_tiles(2);
    let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
    let machine = compiled.options.machine.clone();
    let plan = FaultPlan {
        seed: 11,
        fill_fail_pct: 30,
        stall_pct: 20,
        slow_pct: 10,
        ..FaultPlan::default()
    };
    let x = vec![1.0; spec.grid_points()];
    for core in [SimCore::Dense, SimCore::Event] {
        let session = Session::new(Arc::clone(&compiled), machine.clone())
            .with_sim_core(core)
            .with_fault_plan(Some(plan.clone()));
        let cold = session.run(&x).unwrap();
        allocwatch::reset();
        let warm = session.run(&x).unwrap();
        assert_eq!(
            allocwatch::violations(),
            0,
            "fault/{core}: warm cycle loop allocated"
        );
        assert_eq!(warm.output, cold.output, "fault/{core}: runs diverged");
    }
}
