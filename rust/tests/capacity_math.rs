//! Unit tests pinning the mapper's mandatory-buffering capacity math
//! (§III-B / Fig 8 formulas) against hand-computed values: the 2-D
//! machinery (`map2d`), its 3-D plane-buffered equivalents (`map3d`),
//! and the §IV fused-pipeline accounting (`temporal::required_tokens`)
//! the fused-depth planner budgets with.

use stencil_cgra::stencil::decomp::{self, DecompKind};
use stencil_cgra::stencil::map1d::{tap_capacity_1d, QUEUE_SLACK};
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::{map2d, map3d, temporal, StencilSpec};

#[test]
fn tap_capacity_1d_formula() {
    // 2*t + 2*rx/w + slack(4), hand-checked.
    assert_eq!(QUEUE_SLACK, 4);
    assert_eq!(tap_capacity_1d(8, 1, 0), 20); // 0 + 16 + 4
    assert_eq!(tap_capacity_1d(8, 6, 0), 6); // 0 + 2 + 4
    assert_eq!(tap_capacity_1d(8, 6, 16), 38); // 32 + 2 + 4
    assert_eq!(tap_capacity_1d(1, 3, 2), 8); // 4 + 0 + 4
}

#[test]
fn raw_per_row_partitions_columns() {
    // nx = 21, w = 4: readers own ceil((21 - rho)/4) columns each.
    let spec = StencilSpec::dim2(21, 9, symmetric_taps(2), y_taps(1)).unwrap();
    let per: Vec<usize> = (0..4).map(|rho| map2d::raw_per_row(&spec, rho, 4)).collect();
    assert_eq!(per, vec![6, 5, 5, 5]);
    assert_eq!(per.iter().sum::<usize>(), 21);
    // A reader beyond the grid produces nothing.
    let tiny = StencilSpec::dim2(3, 9, vec![0.1, 0.2, 0.1], vec![0.1, 0.1]).unwrap();
    assert_eq!(map2d::raw_per_row(&tiny, 4, 5), 0);
}

#[test]
fn stage_capacity_is_one_row_plus_slack() {
    let spec = StencilSpec::paper_2d(); // 960 cols
    for (rho, w) in [(0usize, 5usize), (3, 5), (0, 7)] {
        assert_eq!(
            map2d::stage_capacity(&spec, rho, w),
            map2d::raw_per_row(&spec, rho, w) + QUEUE_SLACK
        );
    }
    // 960 / 5 = 192 columns per reader.
    assert_eq!(map2d::stage_capacity(&spec, 0, 5), 192 + 4);
}

#[test]
fn chain_capacity_formula_paper_2d() {
    // 2*k + 2*rx/w + slack; rx = 12, w = 5 -> jitter 4.
    let spec = StencilSpec::paper_2d();
    assert_eq!(map2d::chain_capacity(&spec, 5, 0), 8); // 0 + 4 + 4
    assert_eq!(map2d::chain_capacity(&spec, 5, 1), 10); // 2 + 4 + 4
    assert_eq!(map2d::chain_capacity(&spec, 5, 48), 104); // 96 + 4 + 4
}

#[test]
fn required_buffer_tokens_paper_2d_hand_computed() {
    // Delay lines: 2*ry * (raw + slack) per reader
    //   = 24 * (192 + 4) * 5 readers                  = 23520.
    // Chains: sum_{k=0}^{48} (2k + 8) per worker
    //   = (2 * 48*49/2) + 49*8 = 2352 + 392 = 2744; x5 = 13720.
    let spec = StencilSpec::paper_2d();
    assert_eq!(map2d::required_buffer_tokens(&spec, 5), 23520 + 13720);
}

#[test]
fn required_buffer_tokens_heat2d_hand_computed() {
    // heat2d(20, 14), w = 2: rx = ry = 1.
    // raw: reader 0 owns 10 cols, reader 1 owns 10 -> stage cap 14 each.
    // Delay: 2*ry * 14 * 2 readers = 56.
    // Chains: 5 taps, jitter 2*1/2 = 1 -> caps 5,7,9,11,13 = 45; x2 = 90.
    let spec = StencilSpec::heat2d(20, 14, 0.2);
    assert_eq!(map2d::required_buffer_tokens(&spec, 2), 56 + 90);
}

#[test]
fn map3d_stage_capacity_matches_map2d_row_size() {
    let spec = StencilSpec::heat3d(20, 10, 8, 0.1);
    for rho in 0..3 {
        assert_eq!(
            map3d::stage_capacity(&spec, rho, 3),
            map2d::raw_per_row(&spec, rho, 3) + QUEUE_SLACK
        );
        assert_eq!(map3d::raw_per_row(&spec, rho, 3), map2d::raw_per_row(&spec, rho, 3));
    }
}

#[test]
fn map3d_tap_stage_hand_computed() {
    // ny = 6, ry = rz = 1: alignment point rz*ny + ry = 7.
    let spec = StencilSpec::dim3(
        12,
        6,
        5,
        symmetric_taps(1),
        y_taps(1),
        z_taps(1),
    )
    .unwrap();
    assert_eq!(map3d::tap_stage(&spec, 0, 0), 7); // x taps
    assert_eq!(map3d::tap_stage(&spec, 0, -1), 8); // y = -1
    assert_eq!(map3d::tap_stage(&spec, 0, 1), 6); // y = +1
    assert_eq!(map3d::tap_stage(&spec, -1, 0), 13); // z = -1: a full plane deeper
    assert_eq!(map3d::tap_stage(&spec, 1, 0), 1); // z = +1
    // Star line depth = 2*rz*ny + ry.
    assert_eq!(map3d::delay_stages(&spec, 2), 13);
}

#[test]
fn map3d_box_delay_is_plane_plus_row_on_both_sides() {
    // Box corner needs 2*(rz*ny + ry) stages: ny = 7 -> 2*(7+1) = 16.
    let spec = StencilSpec::box3d(10, 7, 5, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
    assert_eq!(map3d::delay_stages(&spec, 1), 16);
}

#[test]
fn map3d_required_buffer_tokens_hand_computed() {
    // heat3d(10, 6, 5), w = 2: rx = ry = rz = 1.
    // raw: 5 cols per reader -> stage cap 9. Stages = 2*1*6 + 1 = 13.
    // Delay: 13 * 9 * 2 readers = 234.
    // Chains: 7 taps, jitter 2*1/2 = 1 -> caps 5,7,9,11,13,15,17 = 77; x2 = 154.
    let spec = StencilSpec::heat3d(10, 6, 5, 0.1);
    assert_eq!(map3d::required_buffer_tokens(&spec, 2), 234 + 154);
}

#[test]
fn temporal_tokens_at_depth_one_equal_single_step_mapper() {
    // `steps = 1` must reproduce exactly what the single-step mapper
    // counts — the fused planner's budget math degenerates cleanly.
    let cases = [
        (StencilSpec::dim1(64, symmetric_taps(2)).unwrap(), 2usize),
        (StencilSpec::heat2d(20, 14, 0.2), 2),
        (StencilSpec::paper_2d(), 5),
        (StencilSpec::heat3d(10, 6, 5, 0.1), 2),
        (
            StencilSpec::box3d(9, 7, 5, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap(),
            2,
        ),
    ];
    for (spec, w) in cases {
        assert_eq!(
            temporal::required_tokens(&spec, w, 1),
            decomp::required_tokens(&spec, w),
            "dims {:?} w={w}",
            spec.dims()
        );
    }
}

#[test]
fn temporal_tokens_2d_hand_computed() {
    // heat2d(20, 14), w = 2, depth 2.
    // Layer 0 = the single-step count: 56 + 90 = 146 (above).
    // Layer 1 streams cover cols [1, 19): 9 per worker -> stage cap 13;
    //   delay 2*ry * 13 * 2 streams = 52; chains 90 again -> 142.
    let spec = StencilSpec::heat2d(20, 14, 0.2);
    assert_eq!(temporal::required_tokens(&spec, 2, 2), 146 + 142);
}

#[test]
fn temporal_tokens_monotone_in_fused_depth() {
    let specs = [
        StencilSpec::dim1(80, symmetric_taps(2)).unwrap(),
        StencilSpec::heat2d(24, 18, 0.2),
        StencilSpec::heat3d(14, 10, 8, 0.1),
        StencilSpec::box2d(20, 14, 1, 1, uniform_box_taps(1, 1, 0)).unwrap(),
    ];
    for spec in &specs {
        for steps in 1..4 {
            assert!(
                temporal::required_tokens(spec, 2, steps + 1)
                    > temporal::required_tokens(spec, 2, steps),
                "dims {:?} steps={steps}",
                spec.dims()
            );
        }
    }
}

#[test]
fn fused_plan_depth_respects_tile_budget() {
    // Whatever depth the planner picks, the worst tile's fused pipeline
    // must fit the budget it was given.
    let spec = StencilSpec::heat2d(48, 28, 0.2);
    let w = 2;
    for budget in [
        temporal::required_tokens(&spec, w, 1),
        temporal::required_tokens(&spec, w, 3),
    ] {
        let p = decomp::plan_fused(&spec, w, budget, DecompKind::Slab, 1, 4).unwrap();
        let worst = p
            .tiles
            .iter()
            .map(|t| temporal::required_tokens(&t.sub_spec(&spec), w, p.fused_steps))
            .max()
            .unwrap();
        assert!(worst <= budget, "depth {}: {worst} > {budget}", p.fused_steps);
    }
}

#[test]
fn buffering_grows_monotonically_with_each_radius() {
    // More radius in any dimension must demand more on-fabric tokens.
    let base = StencilSpec::heat3d(16, 10, 8, 0.1);
    let more_y = StencilSpec::dim3(
        16,
        10,
        8,
        symmetric_taps(1),
        y_taps(2),
        z_taps(1),
    )
    .unwrap();
    let more_z = StencilSpec::dim3(
        16,
        10,
        8,
        symmetric_taps(1),
        y_taps(1),
        z_taps(2),
    )
    .unwrap();
    let w = 2;
    let b = map3d::required_buffer_tokens(&base, w);
    assert!(map3d::required_buffer_tokens(&more_y, w) > b);
    assert!(map3d::required_buffer_tokens(&more_z, w) > b);
}
