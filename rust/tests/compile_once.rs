//! Counter pins for the compile-once contract.
//!
//! `stencil::metrics` counts every decomposition plan and every DFG
//! construction process-wide. These tests assert *deltas*, so they
//! serialize on a local mutex (and live in their own test binary so no
//! other test's planning runs concurrently).

use std::sync::{Arc, Mutex, MutexGuard};

use stencil_cgra::cgra::Machine;
use stencil_cgra::compile::{compile, CompileCache, CompileOptions};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::{metrics, StencilSpec};
use stencil_cgra::util::rng::XorShift;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn counters() -> (u64, u64) {
    (metrics::plans(), metrics::graph_builds())
}

/// Acceptance pin: executing the same `CompiledStencil` any number of
/// times performs planning and DFG construction exactly once — at
/// compile time.
#[test]
fn executing_a_compiled_stencil_never_replans() {
    let _g = lock();
    let spec = StencilSpec::heat2d(26, 14, 0.2);
    let opts = CompileOptions::default().with_workers(2).with_tiles(2);

    let (p0, g0) = counters();
    let compiled = Arc::new(compile(&spec, 2, &opts).unwrap());
    let (p1, g1) = counters();
    assert!(p1 > p0, "compile must plan");
    assert!(g1 > g0, "compile must build graphs");

    let session = Session::new(Arc::clone(&compiled), Machine::paper());
    let x = XorShift::new(0xABCD).normal_vec(spec.grid_points());
    let a = session.run(&x).unwrap();
    let b = session.run(&x).unwrap();
    let (p2, g2) = counters();
    assert_eq!(p2, p1, "Session::run must not plan");
    assert_eq!(g2, g1, "Session::run must not build graphs");
    assert_eq!(a.output, b.output, "repeat executions are bitwise identical");
}

/// Plan-cache pin: a second `compile` through the cache with an equal
/// `(spec, steps, options)` key does zero decomposition and zero graph
/// work, and returns the same artifact.
#[test]
fn cache_hit_does_zero_planning_and_graph_work() {
    let _g = lock();
    let cache = CompileCache::new(8);
    let spec = StencilSpec::heat2d(30, 16, 0.2);
    let opts = CompileOptions::default().with_workers(2);

    let first = cache.get_or_compile(&spec, 3, &opts).unwrap();
    let (p1, g1) = counters();
    let second = cache.get_or_compile(&spec, 3, &opts).unwrap();
    let (p2, g2) = counters();
    assert!(Arc::ptr_eq(&first, &second), "hit returns the cached artifact");
    assert_eq!(p2, p1, "cache hit must not plan");
    assert_eq!(g2, g1, "cache hit must not build graphs");

    // A different key misses and does real work again.
    let third = cache.get_or_compile(&spec, 4, &opts).unwrap();
    let (p3, g3) = counters();
    assert!(!Arc::ptr_eq(&second, &third));
    assert!(p3 > p2 && g3 > g2, "cache miss compiles");
}

/// Loading a saved artifact rebuilds graphs (deterministically) but
/// never re-runs the budget search: the plan is taken from the file.
#[test]
fn loading_an_artifact_rebuilds_graphs_without_replanning() {
    let _g = lock();
    let spec = StencilSpec::heat2d(24, 12, 0.2);
    let opts = CompileOptions::default().with_workers(2);
    let compiled = compile(&spec, 2, &opts).unwrap();
    let text = compiled.to_text();

    let (p1, g1) = counters();
    let loaded = stencil_cgra::compile::CompiledStencil::parse(&text).unwrap();
    let (p2, g2) = counters();
    assert_eq!(p2, p1, "load takes the plan from the file");
    assert!(g2 > g1, "load rebuilds the placed graphs");
    assert_eq!(loaded.stages[0].plan, compiled.stages[0].plan);
}
