//! Corrupt-artifact fuzz: a saved `CompiledStencil` text mangled by
//! deterministic bit flips, truncations, line drops/duplications, and
//! version rewrites must always come back from `parse` as a value or a
//! typed [`ScgraError::MalformedArtifact`] — never a panic, never an
//! unclassified error, and never a huge allocation from declared-vs-
//! actual geometry lies (the parser validates the spec and caps grid
//! points before trusting any number in the file).

use stencil_cgra::compile::{compile, CompileOptions, CompiledStencil};
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;

fn artifact_text() -> String {
    let spec = StencilSpec::dim2(20, 12, symmetric_taps(1), y_taps(1)).unwrap();
    let opts = CompileOptions::default().with_workers(2).with_tiles(2);
    compile(&spec, 2, &opts).unwrap().to_text()
}

/// Every corruption outcome must be `Ok` (the mangled byte landed
/// somewhere harmless) or a `malformed-artifact` error.
fn assert_never_panics(corrupt: &str, what: &str) {
    if let Err(e) = CompiledStencil::parse(corrupt) {
        assert_eq!(e.kind(), "malformed-artifact", "{what}: {e}");
        assert!(!e.is_transient(), "{what}: corruption is permanent");
    }
}

#[test]
fn random_ascii_bit_flips_never_panic() {
    let text = artifact_text();
    let mut rng = XorShift::new(0xC0FFEE);
    for i in 0..300 {
        let mut bytes = text.clone().into_bytes();
        // Flip 1-3 bytes, staying in ASCII so the text remains valid
        // UTF-8 (the artifact itself is pure ASCII).
        for _ in 0..1 + rng.range(0, 3) {
            let at = rng.range(0, bytes.len());
            let mask = 1 + rng.range(0, 127) as u8;
            bytes[at] = (bytes[at] ^ mask) & 0x7f;
        }
        let corrupt = String::from_utf8(bytes).unwrap();
        assert_never_panics(&corrupt, &format!("flip #{i}"));
    }
}

#[test]
fn truncations_at_every_scale_never_panic() {
    let text = artifact_text();
    let mut rng = XorShift::new(0xBEEF);
    for i in 0..100 {
        let cut = rng.range(0, text.len());
        assert_never_panics(&text[..cut], &format!("truncate at {cut} (#{i})"));
    }
    // The empty file and a header-only file are typed errors too.
    assert!(CompiledStencil::parse("").is_err());
    let header_only = text.lines().next().unwrap();
    assert!(CompiledStencil::parse(header_only).is_err());
}

#[test]
fn line_drops_duplications_and_swaps_never_panic() {
    let text = artifact_text();
    let lines: Vec<&str> = text.lines().collect();
    let mut rng = XorShift::new(0xFEED);
    for i in 0..120 {
        let mut l = lines.clone();
        match rng.range(0, 3) {
            0 => {
                l.remove(rng.range(0, l.len()));
            }
            1 => {
                let at = rng.range(0, l.len());
                l.insert(at, l[at]);
            }
            _ => {
                let a = rng.range(0, l.len());
                let b = rng.range(0, l.len());
                l.swap(a, b);
            }
        }
        assert_never_panics(&l.join("\n"), &format!("line edit #{i}"));
    }
}

#[test]
fn wrong_version_line_is_rejected_by_name() {
    let text = artifact_text();
    for bad in [
        text.replace("artifact v1", "artifact v9"),
        text.replace("artifact v1", "artifact"),
        format!("# some other tool's file v1\n{text}"),
    ] {
        let e = CompiledStencil::parse(&bad).unwrap_err();
        assert_eq!(e.kind(), "malformed-artifact", "{e}");
    }
}

#[test]
fn lying_geometry_is_rejected_without_allocating_it() {
    let text = artifact_text();
    for (from, to) in [
        ("nx = 20", "nx = 184467440737095"),
        ("ny = 12", "ny = 999999999999"),
        ("rx = 1", "rx = 4000000000"),
        ("steps = 2", "steps = 0"),
    ] {
        let corrupt = text.replace(from, to);
        assert_ne!(corrupt, text, "replace `{from}` matched nothing");
        let e = CompiledStencil::parse(&corrupt).unwrap_err();
        assert_eq!(e.kind(), "malformed-artifact", "{from} -> {to}: {e}");
    }
}
