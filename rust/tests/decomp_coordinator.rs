//! Decomposition edge cases and multi-tile differential tests: the
//! N-dim tile path (`stencil::decomp` + `coordinator`) against the
//! golden oracles and against the single-tile whole-grid simulation
//! (which must agree *bitwise* — same chain order, same f64 values).

use stencil_cgra::cgra::Machine;
use stencil_cgra::coordinator::Coordinator;
use stencil_cgra::stencil::decomp::{self, DecompKind, DEFAULT_FABRIC_TOKENS};
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::{StencilShape, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil_ref};

/// Hand-built spec (the constructors reject these shapes) to pin the
/// decomposition layer's own guards.
fn raw_star_spec(
    dims: (usize, usize, usize),
    radii: (usize, usize, usize),
) -> StencilSpec {
    StencilSpec {
        shape: StencilShape::Star,
        nx: dims.0,
        ny: dims.1,
        nz: dims.2,
        rx: radii.0,
        ry: radii.1,
        rz: radii.2,
        cx: vec![0.1; 2 * radii.0 + 1],
        cy: vec![0.1; 2 * radii.1],
        cz: vec![0.1; 2 * radii.2],
        box_taps: Vec::new(),
    }
}

#[test]
fn zero_width_interior_is_an_error() {
    // nx == 2*rx: the interior along x is empty.
    let spec = raw_star_spec((4, 9, 1), (2, 1, 0));
    for kind in [
        DecompKind::Slab,
        DecompKind::Pencil,
        DecompKind::Block,
        DecompKind::Auto,
    ] {
        let err = decomp::plan(&spec, 2, DEFAULT_FABRIC_TOKENS, kind, 4);
        assert!(err.is_err(), "kind {kind} accepted an empty interior");
    }
}

#[test]
fn radius_exceeding_extent_is_an_error() {
    // ry > ny/2 on a 2-D grid; also the degenerate radius == extent.
    let spec = raw_star_spec((12, 2, 1), (1, 2, 0));
    assert!(decomp::plan(&spec, 1, DEFAULT_FABRIC_TOKENS, DecompKind::Slab, 2).is_err());
    let spec3 = raw_star_spec((12, 9, 2), (1, 1, 1));
    assert!(decomp::plan(&spec3, 1, DEFAULT_FABRIC_TOKENS, DecompKind::Block, 2).is_err());
}

#[test]
fn tile_count_exceeding_interior_is_clamped_not_an_error() {
    // 1-D: interior 16 but 64 tiles requested.
    let spec = StencilSpec::dim1(20, symmetric_taps(2)).unwrap();
    let plan = decomp::plan(&spec, 1, DEFAULT_FABRIC_TOKENS, DecompKind::Auto, 64).unwrap();
    assert!(!plan.tiles.is_empty() && plan.tiles.len() <= 16);
    let owned: usize = plan.tiles.iter().map(|t| t.out_points()).sum();
    assert_eq!(owned, 16, "every interior output owned exactly once");

    // And the coordinator still runs it end to end.
    let mut rng = XorShift::new(0xC1A0);
    let x = rng.normal_vec(20);
    let coord = Coordinator::new(64, Machine::paper());
    let rep = coord.run(&spec, 1, &x).unwrap();
    let want = stencil_ref(&x, &spec);
    assert!(max_abs_diff(&rep.output, &want) < 1e-11);
}

#[test]
fn pencil_3d_matches_single_tile_bit_for_bit() {
    // The acceptance differential: a pencil-decomposed 3-D run must be
    // bitwise identical to the single-tile whole-grid path (identical
    // MAC-chain order over identical values) and match the golden
    // oracle within 1e-11.
    let spec = StencilSpec::dim3(18, 14, 10, symmetric_taps(1), y_taps(1), z_taps(1))
        .unwrap();
    let mut rng = XorShift::new(0x3DD1);
    let x = rng.normal_vec(spec.grid_points());

    let multi = Coordinator::new(8, Machine::paper()).with_decomp(DecompKind::Pencil);
    let rep = multi.run(&spec, 2, &x).unwrap();
    assert!(rep.strips > 1, "pencil must produce multiple tiles");
    assert_eq!(rep.kind, DecompKind::Pencil);
    assert_eq!(rep.cuts[0], 1, "pencil keeps x contiguous");
    assert!(rep.halo_points > 0);
    assert!(rep.redundant_read_fraction > 0.0);

    let single = Coordinator::new(1, Machine::paper()).run(&spec, 2, &x).unwrap();
    assert_eq!(single.strips, 1);
    assert_eq!(
        rep.output, single.output,
        "multi-tile output must be bitwise identical to single-tile"
    );

    let want = stencil_ref(&x, &spec);
    assert!(max_abs_diff(&rep.output, &want) < 1e-11);
}

#[test]
fn block_3d_box_stencil_matches_oracle() {
    let spec = StencilSpec::box3d(12, 10, 8, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
    let mut rng = XorShift::new(0xB0C5);
    let x = rng.normal_vec(spec.grid_points());
    let coord = Coordinator::new(8, Machine::paper()).with_decomp(DecompKind::Block);
    let rep = coord.run(&spec, 2, &x).unwrap();
    assert!(rep.strips >= 8);
    let want = stencil_ref(&x, &spec);
    assert!(max_abs_diff(&rep.output, &want) < 1e-11);
}

#[test]
fn slab_2d_multi_tile_still_matches_through_tile_path() {
    // The legacy 1-axis strips are now slab tiles; the differential
    // guarantee carries over.
    let spec = StencilSpec::dim2(48, 18, symmetric_taps(2), y_taps(2)).unwrap();
    let mut rng = XorShift::new(0x51AB);
    let x = rng.normal_vec(spec.grid_points());
    let coord = Coordinator::new(4, Machine::paper()).with_decomp(DecompKind::Slab);
    let rep = coord.run(&spec, 2, &x).unwrap();
    assert!(rep.strips >= 4);
    assert_eq!(rep.cuts[1], 1);
    let single = Coordinator::new(1, Machine::paper()).run(&spec, 2, &x).unwrap();
    assert_eq!(rep.output, single.output);
    let want = stencil_ref(&x, &spec);
    assert!(max_abs_diff(&rep.output, &want) < 1e-11);
}

#[test]
fn reported_redundant_reads_equal_measured_including_tail() {
    // Satellite accounting pin: under `reload` the geometric fraction a
    // report carries must equal what the simulators actually loaded —
    // per chunk AND as the workload aggregate, tail stage included
    // (the tail fuses fewer steps, so its halos are narrower and its
    // fraction smaller; a stage-0-only aggregate would overstate it).
    use std::sync::Arc;
    use stencil_cgra::compile::{compile, CompileOptions, FuseMode, HaloMode};
    use stencil_cgra::session::Session;

    // ny = 10 caps the trapezoid at depth 3 (need ny > 2T), so steps = 7
    // always leaves a tail stage (7 % d != 0 for d in 2..=3).
    let spec = StencilSpec::heat2d(40, 10, 0.2);
    let mut rng = XorShift::new(0x2ED5);
    let x = rng.normal_vec(spec.grid_points());
    let opts = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_fuse(FuseMode::Spatial)
        .with_halo(HaloMode::Reload);
    let compiled = Arc::new(compile(&spec, 7, &opts).unwrap());
    let depth = compiled.fused_steps();
    assert!((2..=3).contains(&depth));
    assert_eq!(compiled.stages.len(), 2, "7 % {depth} != 0 leaves a tail");
    let machine = compiled.options.machine.clone();
    let out = Session::new(Arc::clone(&compiled), machine).run(&x).unwrap();

    let grid = spec.grid_points() as f64;
    for (i, r) in out.reports.iter().enumerate() {
        let measured = r.total_loads() as f64 / grid - 1.0;
        assert!(
            (r.redundant_read_fraction - measured).abs() < 1e-12,
            "chunk {i}: reported {} vs measured {measured}",
            r.redundant_read_fraction
        );
        assert_eq!(r.total_loads(), r.dram_point_reads(), "reload never exchanges");
        assert_eq!(r.exchanged_points, 0);
    }
    // The tail chunk fuses fewer steps, so its halos — and fraction —
    // are strictly narrower than the primary stage's.
    let (first, tail) = (&out.reports[0], out.reports.last().unwrap());
    assert!(tail.fused_steps < first.fused_steps);
    assert!(tail.redundant_read_fraction < first.redundant_read_fraction);

    // Workload aggregate, tail included: the artifact-level fraction
    // equals the measured mean over all chunks.
    let chunks = out.reports.len() as f64;
    let measured_total: f64 =
        out.reports.iter().map(|r| r.total_loads() as f64).sum::<f64>() / (grid * chunks)
            - 1.0;
    assert!(
        (compiled.redundant_read_fraction() - measured_total).abs() < 1e-12,
        "workload: reported {} vs measured {measured_total}",
        compiled.redundant_read_fraction()
    );
}

#[test]
fn acoustic_shape_runs_on_16_tiles_via_pencil() {
    // Scaled-down version of the acoustic_3d example's acceptance
    // criterion: 16 tiles, pencil cuts, oracle agreement, and halo
    // accounting in the report.
    let spec = StencilSpec::dim3(16, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2))
        .unwrap();
    let mut rng = XorShift::new(0xAC16);
    let x = rng.normal_vec(spec.grid_points());
    let coord = Coordinator::paper().with_decomp(DecompKind::Pencil);
    let rep = coord.run(&spec, 2, &x).unwrap();
    assert_eq!(rep.strips, 16, "4 y-cuts x 4 z-cuts feed all 16 tiles");
    assert_eq!(rep.cuts, [1, 4, 4]);
    let want = stencil_ref(&x, &spec);
    assert!(max_abs_diff(&rep.output, &want) < 1e-11);
    assert!(rep.halo_points > 0);
    assert_eq!(
        rep.per_tile.iter().map(|t| t.strips).sum::<usize>(),
        rep.strips
    );
}
