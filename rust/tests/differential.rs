//! Differential test harness: seeded-random stencil specifications run
//! through the full mapper → placement → cycle-simulator stack
//! (`verify::golden::run_sim`) and compared element-wise against the
//! native golden oracles, `max_abs_diff < 1e-9`.
//!
//! Coverage: star 1-D/2-D/3-D, box 2-D/3-D, and the §IV temporal
//! multi-step pipeline (checked against `steps` applications of the
//! single-step oracle over the shrinking `valid_range`).

use stencil_cgra::cgra::{Machine, Simulator};
use stencil_cgra::stencil::{temporal, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{
    max_abs_diff, run_sim, stencil1d_ref, stencil2d_ref, stencil_ref, stencil_ref_steps,
};

const TOL: f64 = 1e-9;

/// Random coefficient in roughly [-0.5, 0.5] — bounded so iterated and
/// long-chain accumulations stay far from the 1e-9 tolerance.
fn coeff(rng: &mut XorShift) -> f64 {
    0.3 * rng.normal()
}

fn coeffs(rng: &mut XorShift, n: usize) -> Vec<f64> {
    (0..n).map(|_| coeff(rng)).collect()
}

#[test]
fn star_1d_random_specs_match_oracle() {
    let mut rng = XorShift::new(0xD1FF_0001);
    let m = Machine::paper();
    for case in 0..8 {
        let r = rng.range(1, 5);
        let nx = rng.range(2 * r + 2, 100);
        let w = rng.range(1, 6);
        let spec = StencilSpec::dim1(nx, coeffs(&mut rng, 2 * r + 1)).unwrap();
        let x = rng.normal_vec(nx);
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let want = stencil1d_ref(&x, &spec.cx);
        assert!(
            max_abs_diff(&res.output, &want) < TOL,
            "case {case}: nx={nx} r={r} w={w}"
        );
        // The legacy and generic oracles agree bitwise.
        assert_eq!(want, stencil_ref(&x, &spec));
    }
}

#[test]
fn star_2d_random_specs_match_oracle() {
    let mut rng = XorShift::new(0xD1FF_0002);
    let m = Machine::paper();
    for case in 0..6 {
        let rx = rng.range(1, 4);
        let ry = rng.range(1, 4);
        let nx = rng.range(2 * rx + 2, 30);
        let ny = rng.range(2 * ry + 2, 24);
        let w = rng.range(1, 5);
        let spec = StencilSpec::dim2(
            nx,
            ny,
            coeffs(&mut rng, 2 * rx + 1),
            coeffs(&mut rng, 2 * ry),
        )
        .unwrap();
        let x = rng.normal_vec(nx * ny);
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let want = stencil2d_ref(&x, &spec);
        assert!(
            max_abs_diff(&res.output, &want) < TOL,
            "case {case}: {nx}x{ny} r=({rx},{ry}) w={w}"
        );
        assert_eq!(want, stencil_ref(&x, &spec));
    }
}

#[test]
fn star_3d_random_specs_match_oracle() {
    let mut rng = XorShift::new(0xD1FF_0003);
    let m = Machine::paper();
    for case in 0..5 {
        let rx = rng.range(1, 3);
        let ry = rng.range(1, 3);
        let rz = rng.range(1, 3);
        let nx = rng.range(2 * rx + 2, 16);
        let ny = rng.range(2 * ry + 2, 12);
        let nz = rng.range(2 * rz + 2, 10);
        let w = rng.range(1, 4);
        let spec = StencilSpec::dim3(
            nx,
            ny,
            nz,
            coeffs(&mut rng, 2 * rx + 1),
            coeffs(&mut rng, 2 * ry),
            coeffs(&mut rng, 2 * rz),
        )
        .unwrap();
        let x = rng.normal_vec(nx * ny * nz);
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(
            max_abs_diff(&res.output, &want) < TOL,
            "case {case}: {nx}x{ny}x{nz} r=({rx},{ry},{rz}) w={w}"
        );
    }
}

#[test]
fn box_2d_random_specs_match_oracle() {
    let mut rng = XorShift::new(0xD1FF_0004);
    let m = Machine::paper();
    for case in 0..5 {
        let rx = rng.range(1, 3);
        let ry = rng.range(1, 3);
        let nx = rng.range(2 * rx + 2, 26);
        let ny = rng.range(2 * ry + 2, 20);
        let w = rng.range(1, 4);
        let taps = coeffs(&mut rng, (2 * rx + 1) * (2 * ry + 1));
        let spec = StencilSpec::box2d(nx, ny, rx, ry, taps).unwrap();
        let x = rng.normal_vec(nx * ny);
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(
            max_abs_diff(&res.output, &want) < TOL,
            "case {case}: {nx}x{ny} r=({rx},{ry}) w={w}"
        );
    }
}

#[test]
fn box_3d_random_specs_match_oracle() {
    let mut rng = XorShift::new(0xD1FF_0005);
    let m = Machine::paper();
    for case in 0..3 {
        let nx = rng.range(5, 12);
        let ny = rng.range(5, 10);
        let nz = rng.range(5, 8);
        let w = rng.range(1, 3);
        let taps = coeffs(&mut rng, 27);
        let spec = StencilSpec::box3d(nx, ny, nz, 1, 1, 1, taps).unwrap();
        let x = rng.normal_vec(nx * ny * nz);
        let res = run_sim(&spec, w, &m, &x).unwrap();
        let want = stencil_ref(&x, &spec);
        assert!(
            max_abs_diff(&res.output, &want) < TOL,
            "case {case}: {nx}x{ny}x{nz} w={w}"
        );
    }
}

#[test]
fn temporal_random_specs_match_iterated_oracle() {
    let mut rng = XorShift::new(0xD1FF_0006);
    let m = Machine::paper();
    for case in 0..5 {
        let r = rng.range(1, 3);
        let steps = rng.range(2, 5);
        let nx = rng.range(2 * r * steps + 4, 80);
        let w = rng.range(1, 4);
        let spec = StencilSpec::dim1(nx, coeffs(&mut rng, 2 * r + 1)).unwrap();
        let x = rng.normal_vec(nx);
        let g = temporal::build(&spec, w, steps).unwrap();
        let res = Simulator::build(g, &m, x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        let want = stencil_ref_steps(&spec, &x, steps);
        let (lo, hi) = temporal::valid_range(&spec, steps);
        let got = &res.output[lo..hi];
        assert!(
            max_abs_diff(got, &want[lo..hi]) < TOL,
            "case {case}: nx={nx} r={r} steps={steps} w={w}"
        );
    }
}
