//! End-to-end: the full stack in one test — coordinator-driven
//! multi-step heat diffusion on simulated tiles, validated against both
//! the native oracle and the PJRT-executed fused JAX artifact.

use stencil_cgra::cgra::Machine;
use stencil_cgra::coordinator::Coordinator;
use stencil_cgra::runtime::Runtime;
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::verify::golden::max_abs_diff;

#[test]
fn heat_diffusion_all_layers_agree_over_20_steps() {
    let (nx, ny, steps, alpha) = (96usize, 96usize, 20usize, 0.2);
    let spec = StencilSpec::heat2d(nx, ny, alpha);
    let mut x = vec![0.0; nx * ny];
    x[48 * 96 + 48] = 100.0;

    // L3: coordinator over 4 simulated tiles, host-driven steps.
    let coord = Coordinator::new(4, Machine::paper());
    let (cgra_out, reports) = coord.run_steps(&spec, 2, &x, steps).unwrap();
    assert_eq!(reports.len(), steps);

    // L2/L1 through PJRT: iterate the single-step artifact.
    let rt = Runtime::open(Runtime::default_dir()).unwrap();
    let mut pjrt_out = x.clone();
    for _ in 0..steps {
        pjrt_out = rt.execute("heat2d_step_96x96", &[&pjrt_out]).unwrap();
    }

    let d = max_abs_diff(&cgra_out, &pjrt_out);
    assert!(d < 1e-10, "CGRA-sim vs PJRT drifted: {d:.3e}");

    // Physics sanity.
    let peak = cgra_out[48 * 96 + 48];
    assert!(peak < 100.0 && peak > 0.0);
    assert!(cgra_out.iter().all(|&v| v >= -1e-12));
}

#[test]
fn throughput_accounting_is_consistent() {
    let spec = StencilSpec::heat2d(64, 64, 0.2);
    let x = vec![1.0; 64 * 64];
    let coord = Coordinator::new(2, Machine::paper());
    let rep = coord.run(&spec, 2, &x).unwrap();
    // flops = 9 per output * interior.
    let want_flops = 9.0 * (62 * 62) as f64;
    assert!((rep.total_flops - want_flops).abs() < 1.0);
    // gflops = flops * clock / makespan.
    let expect = rep.total_flops * coord.machine.clock_ghz / rep.makespan_cycles as f64;
    assert!((rep.gflops - expect).abs() < 1e-9);
}
