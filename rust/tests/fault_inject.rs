//! Chaos matrix for the resilience layer: deterministic fault plans
//! (transient fill failures, channel stall windows, PE slow-down
//! epochs) crossed with star/box stencils in 1/2/3-D, both scheduler
//! cores, and pooled/sequential execution.
//!
//! The contracts under test:
//!   * faults change *timing*, never *values* — every faulted run's
//!     output is bit-identical to the fault-free run of the same plan;
//!   * the dense and event cores replay a fault plan bit-identically
//!     (same outputs, same makespans, same per-task trace fingerprints
//!     including retried-fill counts);
//!   * a fill-failure plan is actually exercised (`MemStats::retries`
//!     lands in the reports);
//!   * an expired deadline returns a typed partial outcome promptly —
//!     no hang, and the session (including its worker pool) remains
//!     usable for the next run.

use std::sync::Arc;
use std::time::{Duration, Instant};

use stencil_cgra::cgra::SimCore;
use stencil_cgra::compile::{compile, CompileOptions, CompiledStencil};
use stencil_cgra::session::{ExecMode, Outcome, Session};
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::FaultPlan;

/// The workload axis: star/box crossed with 1/2/3-D, tile counts
/// mirroring the proven alloc-free matrix.
fn workloads() -> Vec<(&'static str, StencilSpec, usize)> {
    vec![
        ("star1d", StencilSpec::dim1(72, symmetric_taps(2)).unwrap(), 1),
        (
            "star2d",
            StencilSpec::dim2(24, 14, symmetric_taps(1), y_taps(1)).unwrap(),
            2,
        ),
        (
            "star3d",
            StencilSpec::dim3(12, 8, 6, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap(),
            1,
        ),
        (
            "box2d",
            StencilSpec::box2d(18, 12, 1, 1, uniform_box_taps(1, 1, 0)).unwrap(),
            1,
        ),
        (
            "box3d",
            StencilSpec::box3d(10, 8, 6, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap(),
            1,
        ),
    ]
}

/// The fault axis: each mechanism alone, then all three together.
fn plans() -> Vec<(&'static str, FaultPlan)> {
    vec![
        (
            "fill",
            FaultPlan {
                seed: 3,
                fill_fail_pct: 35,
                ..FaultPlan::default()
            },
        ),
        (
            "stall",
            FaultPlan {
                seed: 5,
                stall_pct: 30,
                stall_extra: 6,
                ..FaultPlan::default()
            },
        ),
        (
            "slow",
            FaultPlan {
                seed: 7,
                slow_pct: 25,
                epoch_cycles: 64,
                ..FaultPlan::default()
            },
        ),
        (
            "mixed",
            FaultPlan::parse("seed=11 fill=20 stall=15 extra=4 slow=10 epoch=128").unwrap(),
        ),
    ]
}

fn compiled_for(spec: &StencilSpec, tiles: usize) -> Arc<CompiledStencil> {
    let opts = CompileOptions::default().with_workers(2).with_tiles(tiles);
    Arc::new(compile(spec, 2, &opts).unwrap())
}

fn session_for(
    compiled: &Arc<CompiledStencil>,
    core: SimCore,
    exec: ExecMode,
    fault: Option<FaultPlan>,
) -> Session {
    let machine = compiled.options.machine.clone();
    Session::new(Arc::clone(compiled), machine)
        .with_sim_core(core)
        .with_exec(exec)
        .with_fault_plan(fault)
}

fn total_retries(out: &stencil_cgra::RunOutcome) -> u64 {
    out.reports
        .iter()
        .map(|r| {
            r.ring_mem.retries + r.per_tile.iter().map(|t| t.mem.retries).sum::<u64>()
        })
        .sum()
}

#[test]
fn chaos_matrix_is_value_exact_and_core_identical() {
    for (wname, spec, tiles) in workloads() {
        let compiled = compiled_for(&spec, tiles);
        let input = XorShift::new(42).normal_vec(spec.grid_points());
        // Fault-free oracle under the default (event) core.
        let clean = session_for(&compiled, SimCore::Event, ExecMode::Sequential, None)
            .run(&input)
            .unwrap();
        assert_eq!(clean.outcome, Outcome::Complete);

        for (pname, plan) in plans() {
            let mut per_core = Vec::new();
            for core in [SimCore::Dense, SimCore::Event] {
                for exec in [ExecMode::Pooled, ExecMode::Sequential] {
                    let s = session_for(&compiled, core, exec, Some(plan.clone()));
                    let (out, trace) = s.run_recorded(&input).unwrap();
                    assert_eq!(
                        out.outcome,
                        Outcome::Complete,
                        "{wname}/{pname}/{core}/{exec:?}"
                    );
                    // Faults never change values: bit-identical to the
                    // fault-free grid.
                    assert_eq!(
                        out.output, clean.output,
                        "{wname}/{pname}/{core}/{exec:?}: faulted values diverged"
                    );
                    if pname == "fill" || pname == "mixed" {
                        assert!(
                            total_retries(&out) > 0,
                            "{wname}/{pname}/{core}/{exec:?}: fill plan never retried"
                        );
                    }
                    per_core.push((core, exec, out, trace));
                }
            }
            // Pooled and sequential execution of the same core agree,
            // and the two cores replay the plan bit-identically: same
            // makespans, retries, and per-task fingerprints (cycles,
            // fires, tickets, fire/output hashes; wakeups excluded).
            let (_, _, ref_out, ref_trace) = &per_core[0];
            for (core, exec, out, trace) in &per_core[1..] {
                let ctx = format!("{wname}/{pname}/{core}/{exec:?} vs dense/pooled");
                assert_eq!(out.output, ref_out.output, "{ctx}: outputs");
                assert_eq!(out.reports.len(), ref_out.reports.len(), "{ctx}: chunks");
                for (a, b) in out.reports.iter().zip(&ref_out.reports) {
                    assert_eq!(a.makespan_cycles, b.makespan_cycles, "{ctx}: makespan");
                    assert_eq!(a.total_cycles, b.total_cycles, "{ctx}: total cycles");
                }
                assert_eq!(total_retries(out), total_retries(ref_out), "{ctx}: retries");
                trace.matches(ref_trace).unwrap_or_else(|e| {
                    panic!("{ctx}: trace diverged: {e}");
                });
            }
        }
    }
}

#[test]
fn expired_deadline_is_a_prompt_typed_partial_and_the_pool_survives() {
    let spec = StencilSpec::dim2(24, 14, symmetric_taps(1), y_taps(1)).unwrap();
    let compiled = compiled_for(&spec, 2);
    let input = XorShift::new(7).normal_vec(spec.grid_points());

    for exec in [ExecMode::Pooled, ExecMode::Sequential] {
        let machine = compiled.options.machine.clone();
        let session = Session::new(Arc::clone(&compiled), machine)
            .with_exec(exec)
            .with_deadline(Some(Duration::ZERO));
        let t0 = Instant::now();
        let out = session.run(&input).unwrap();
        // Prompt: an already-expired deadline must not simulate first.
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{exec:?}: cancellation was not prompt"
        );
        match out.outcome {
            Outcome::DeadlineExceeded {
                completed_tasks,
                total_tasks,
            } => {
                assert_eq!(completed_tasks, 0, "{exec:?}");
                assert!(total_tasks > 0, "{exec:?}");
            }
            Outcome::Complete => panic!("{exec:?}: zero deadline completed"),
        }
        assert!(out.reports.is_empty(), "{exec:?}: partial run reported chunks");
        assert_eq!(out.output, input, "{exec:?}: partial output is the last full grid");

        // The same session runs to completion once the deadline lifts:
        // no leaked tasks, no poisoned pool, no stuck cancel flag.
        let session = session.with_deadline(None);
        let full = session.run(&input).unwrap();
        assert_eq!(full.outcome, Outcome::Complete, "{exec:?}");
        let clean = session_for(&compiled, SimCore::Event, ExecMode::Sequential, None)
            .run(&input)
            .unwrap();
        assert_eq!(full.output, clean.output, "{exec:?}: post-deadline run diverged");
    }
}

#[test]
fn faulted_runs_replay_deterministically_within_a_session() {
    // The same armed session, run twice: fault draws are keyed on
    // stable coordinates, so the second run is a bitwise replay of the
    // first — reports, retries, outputs.
    let spec = StencilSpec::dim2(24, 14, symmetric_taps(1), y_taps(1)).unwrap();
    let compiled = compiled_for(&spec, 2);
    let input = XorShift::new(9).normal_vec(spec.grid_points());
    let plan = FaultPlan::parse("seed=13 fill=30 stall=10 extra=4").unwrap();
    for core in [SimCore::Dense, SimCore::Event] {
        let s = session_for(&compiled, core, ExecMode::Pooled, Some(plan.clone()));
        let (a, ta) = s.run_recorded(&input).unwrap();
        let (b, tb) = s.run_recorded(&input).unwrap();
        assert_eq!(a.output, b.output, "{core}: outputs");
        assert_eq!(total_retries(&a), total_retries(&b), "{core}: retries");
        tb.matches(&ta)
            .unwrap_or_else(|e| panic!("{core}: replay diverged: {e}"));
    }
}
