//! Differential and acceptance suite for the inter-tile halo-exchange
//! subsystem (`--halo exchange`).
//!
//! The exchange model is timing/accounting-only: warm chunks keep the
//! previous chunk's faces fabric-resident, so their loads bypass the
//! cache/DRAM model, but the *values* flowing through the MAC chains
//! are untouched. The contract is therefore strict bitwise equality —
//! `==`, never a tolerance — between exchange runs, reload runs, and
//! the iterated golden oracle on the FULL grid, across shapes
//! (star/box), ranks (1/2/3-D), decompositions (slab/pencil/block),
//! both simulator cores, and fused depths 1–3.
//!
//! Every test here plans and builds graphs, and one test pins
//! process-wide `stencil::metrics` deltas, so all tests serialize on a
//! local mutex (the same discipline as `tests/compile_once.rs`).

use std::sync::{Arc, Mutex, MutexGuard};

use stencil_cgra::cgra::SimCore;
use stencil_cgra::compile::{compile, CompileOptions, FuseMode, HaloMode};
use stencil_cgra::session::{RunOutcome, Session};
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{metrics, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::stencil_ref_steps;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn coeffs(rng: &mut XorShift, n: usize) -> Vec<f64> {
    (0..n).map(|_| 0.3 * rng.normal()).collect()
}

/// Compile the same workload under `exchange` and `reload`, run both
/// sessions on `core`, and assert full-grid bitwise equality of
/// exchange vs reload vs the iterated oracle. Returns both outcomes
/// for case-specific accounting pins.
fn run_pair(
    spec: &StencilSpec,
    steps: usize,
    base: &CompileOptions,
    x: &[f64],
    core: SimCore,
) -> (RunOutcome, RunOutcome) {
    let want = stencil_ref_steps(spec, x, steps);
    let mut outs = Vec::new();
    for halo in [HaloMode::Exchange, HaloMode::Reload] {
        let opts = base.clone().with_halo(halo);
        let compiled = Arc::new(compile(spec, steps, &opts).unwrap());
        let machine = compiled.options.machine.clone();
        let out = Session::new(compiled, machine)
            .with_sim_core(core)
            .run(x)
            .unwrap();
        assert_eq!(
            out.output,
            want,
            "dims {:?} steps={steps} core={core} halo={halo}: oracle mismatch",
            spec.dims()
        );
        outs.push(out);
    }
    let reload = outs.pop().unwrap();
    let exchange = outs.pop().unwrap();
    assert_eq!(
        exchange.output,
        reload.output,
        "dims {:?} steps={steps} core={core}: exchange != reload",
        spec.dims()
    );
    (exchange, reload)
}

/// Accounting invariants shared by every exchange run: the first chunk
/// is cold (nothing resident yet) and pays the same DRAM traffic as
/// reload; every later chunk receives its halos in-fabric, reads zero
/// points from DRAM, and reports zero redundancy.
fn assert_exchange_accounting(exchange: &RunOutcome, reload: &RunOutcome) {
    assert_eq!(exchange.reports.len(), reload.reports.len());
    assert!(exchange.reports.len() >= 2, "need warm chunks to exchange");
    let cold = &exchange.reports[0];
    assert_eq!(cold.exchanged_points, 0, "first chunk has no donor");
    assert_eq!(cold.total_loads(), cold.dram_point_reads());
    assert_eq!(
        cold.redundant_read_fraction,
        reload.reports[0].redundant_read_fraction
    );
    for (i, (e, r)) in exchange.reports.iter().zip(&reload.reports).enumerate().skip(1) {
        assert_eq!(e.redundant_read_fraction, 0.0, "warm chunk {i}");
        assert_eq!(e.dram_point_reads(), 0, "warm chunk {i} touched DRAM");
        assert!(e.exchanged_points > 0, "warm chunk {i} exchanged nothing");
        // Same values move through the fabric either way.
        assert_eq!(e.total_loads(), r.total_loads(), "chunk {i} load count");
    }
}

#[test]
fn depth1_star_1d_slab_exchange_matches_reload_bitwise() {
    let _g = lock();
    let spec = StencilSpec::dim1(40, symmetric_taps(2)).unwrap();
    let mut rng = XorShift::new(0x4A10_EE1D);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(3)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Host);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 3, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn depth1_box_3d_block_exchange_matches_reload_bitwise() {
    let _g = lock();
    let mut rng = XorShift::new(0xB0C5_EE01);
    let spec = StencilSpec::box3d(10, 9, 8, 1, 1, 1, coeffs(&mut rng, 27)).unwrap();
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Host);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 2, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn fused_depth2_star_2d_slab_exchange_matches_reload_bitwise() {
    let _g = lock();
    // ny = 6 caps the trapezoid at depth 2 (needs ny > 2T), so steps = 4
    // compiles to two depth-2 chunks: one cold, one warm.
    let spec = StencilSpec::heat2d(30, 6, 0.2);
    let mut rng = XorShift::new(0x5AB0_EE02);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert_eq!(probe.fused_steps(), 2, "geometry must cap the depth at 2");
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        assert!(e.reports.iter().all(|rep| rep.ring_points > 0));
    }
}

#[test]
fn fused_depth3_star_3d_pencil_exchange_matches_reload_bitwise() {
    let _g = lock();
    // nz = 8 caps the trapezoid at depth 3, so 4 steps never fuse into a
    // single chunk — a warm chunk (and a narrower tail) is guaranteed.
    let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
    let mut rng = XorShift::new(0x9E4C_EE03);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Pencil)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert!((2..=3).contains(&probe.fused_steps()));
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn fused_box_2d_block_exchange_matches_reload_bitwise() {
    let _g = lock();
    let mut rng = XorShift::new(0xB0CE_EE04);
    let spec = StencilSpec::box2d(20, 8, 1, 1, coeffs(&mut rng, 9)).unwrap();
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Spatial);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn acceptance_pencil_16_tile_3d_warm_chunks_read_zero_dram() {
    let _g = lock();
    // The headline acceptance pin: a 16-tile pencil 3-D plan (the
    // acoustic shape: cuts [1, 4, 4], radius 2) under `exchange` drives
    // post-warm-up redundant reads to exactly 0 — well under the 0.01
    // budget — while staying bitwise-equal to reload and the oracle.
    let spec = StencilSpec::dim3(16, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2))
        .unwrap();
    let mut rng = XorShift::new(0xAC16_EE05);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(16)
        .with_decomp(DecompKind::Pencil)
        .with_fuse(FuseMode::Host);
    let probe = compile(&spec, 3, &base).unwrap();
    assert_eq!(probe.plan().tiles.len(), 16, "4 y-cuts x 4 z-cuts");
    assert_eq!(probe.plan().cuts, [1, 4, 4]);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 3, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        for rep in &e.reports[1..] {
            assert!(rep.redundant_read_fraction <= 0.01);
            assert_eq!(rep.dram_point_reads(), 0);
        }
        // Reload keeps paying the geometric overlap every chunk.
        assert!(r.reports.iter().all(|rep| rep.redundant_read_fraction > 0.0));
    }
}

#[test]
fn acceptance_block_2d_warm_chunks_read_zero_dram() {
    let _g = lock();
    let spec = StencilSpec::heat2d(24, 8, 0.2);
    let mut rng = XorShift::new(0xB10C_EE06);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert!(probe.total_chunks() >= 2, "ny = 8 caps the depth below 4");
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        for rep in &e.reports[1..] {
            assert!(rep.redundant_read_fraction <= 0.01);
            assert_eq!(rep.dram_point_reads(), 0);
        }
    }
}

#[test]
fn exchange_does_zero_extra_planning_or_graph_work() {
    let _g = lock();
    // The schedules are pure index arithmetic built at compile time:
    // compiling under `exchange` does exactly the same plan/graph work
    // as `reload`, and exchange executions build nothing at all.
    let spec = StencilSpec::heat2d(26, 8, 0.2);
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_fuse(FuseMode::Spatial);

    let (p0, g0) = (metrics::plans(), metrics::graph_builds());
    let exchange = Arc::new(compile(&spec, 4, &base.clone().with_halo(HaloMode::Exchange)).unwrap());
    let (p1, g1) = (metrics::plans(), metrics::graph_builds());
    let _reload = compile(&spec, 4, &base.clone().with_halo(HaloMode::Reload)).unwrap();
    let (p2, g2) = (metrics::plans(), metrics::graph_builds());
    assert_eq!(p1 - p0, p2 - p1, "exchange compile plans extra");
    assert_eq!(g1 - g0, g2 - g1, "exchange compile builds extra graphs");

    let mut rng = XorShift::new(0x0EE0_EE07);
    let x = rng.normal_vec(spec.grid_points());
    let machine = exchange.options.machine.clone();
    let session = Session::new(exchange, machine);
    let a = session.run(&x).unwrap();
    let b = session.run(&x).unwrap();
    let (p3, g3) = (metrics::plans(), metrics::graph_builds());
    assert_eq!(p3, p2, "exchange run must not plan");
    assert_eq!(g3, g2, "exchange run must not build graphs");
    assert_eq!(a.output, b.output);
}
