//! Differential and acceptance suite for the inter-tile halo-exchange
//! subsystem (`--halo exchange`).
//!
//! The exchange model is timing/accounting-only: warm chunks keep the
//! previous chunk's faces fabric-resident, so their loads bypass the
//! cache/DRAM model, but the *values* flowing through the MAC chains
//! are untouched. The contract is therefore strict bitwise equality —
//! `==`, never a tolerance — between priced exchange runs
//! ([`HaloMode::Exchange`]), flat exchange runs
//! ([`HaloMode::ExchangeFree`]), reload runs, and the iterated golden
//! oracle on the FULL grid, across shapes (star/box), ranks (1/2/3-D),
//! decompositions (slab/pencil/block), both simulator cores, both
//! execution modes, and fused depths 1–3. On top of the value contract
//! this suite pins the hop-latency pricing (far neighbors strictly
//! costlier than near ones), the ring/interior overlap (makespan =
//! `max(fused, ring critical)`, trace order independent of overlap),
//! and the residency spill fallback (reported spill == measured DRAM
//! traffic).
//!
//! Every test here plans and builds graphs, and one test pins
//! process-wide `stencil::metrics` deltas, so all tests serialize on a
//! local mutex (the same discipline as `tests/compile_once.rs`).

use std::sync::{Arc, Mutex, MutexGuard};

use stencil_cgra::cgra::{mesh_hop_cycles, SimCore};
use stencil_cgra::compile::{compile, CompileOptions, FuseMode, HaloMode};
use stencil_cgra::session::{ExecMode, RunOutcome, Session};
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::exchange::ExchangeSchedule;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps, z_taps};
use stencil_cgra::stencil::{metrics, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::stencil_ref_steps;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn coeffs(rng: &mut XorShift, n: usize) -> Vec<f64> {
    (0..n).map(|_| 0.3 * rng.normal()).collect()
}

/// Compile the same workload under `exchange` and `reload`, run both
/// sessions on `core`, and assert full-grid bitwise equality of
/// exchange vs reload vs the iterated oracle. Returns both outcomes
/// for case-specific accounting pins.
fn run_pair(
    spec: &StencilSpec,
    steps: usize,
    base: &CompileOptions,
    x: &[f64],
    core: SimCore,
) -> (RunOutcome, RunOutcome) {
    let want = stencil_ref_steps(spec, x, steps);
    let mut outs = Vec::new();
    for halo in [HaloMode::Exchange, HaloMode::Reload] {
        let opts = base.clone().with_halo(halo);
        let compiled = Arc::new(compile(spec, steps, &opts).unwrap());
        let machine = compiled.options.machine.clone();
        let out = Session::new(compiled, machine)
            .with_sim_core(core)
            .run(x)
            .unwrap();
        assert_eq!(
            out.output,
            want,
            "dims {:?} steps={steps} core={core} halo={halo}: oracle mismatch",
            spec.dims()
        );
        outs.push(out);
    }
    let reload = outs.pop().unwrap();
    let exchange = outs.pop().unwrap();
    assert_eq!(
        exchange.output,
        reload.output,
        "dims {:?} steps={steps} core={core}: exchange != reload",
        spec.dims()
    );
    (exchange, reload)
}

/// Accounting invariants shared by every exchange run: the first chunk
/// is cold (nothing resident yet) and pays the same DRAM traffic as
/// reload; every later chunk receives its halos in-fabric, reads zero
/// points from DRAM, and reports zero redundancy.
fn assert_exchange_accounting(exchange: &RunOutcome, reload: &RunOutcome) {
    assert_eq!(exchange.reports.len(), reload.reports.len());
    assert!(exchange.reports.len() >= 2, "need warm chunks to exchange");
    let cold = &exchange.reports[0];
    assert_eq!(cold.exchanged_points, 0, "first chunk has no donor");
    assert_eq!(cold.total_loads(), cold.dram_point_reads());
    assert_eq!(
        cold.redundant_read_fraction,
        reload.reports[0].redundant_read_fraction
    );
    for (i, (e, r)) in exchange.reports.iter().zip(&reload.reports).enumerate().skip(1) {
        assert_eq!(e.redundant_read_fraction, 0.0, "warm chunk {i}");
        assert_eq!(e.dram_point_reads(), 0, "warm chunk {i} touched DRAM");
        assert!(e.exchanged_points > 0, "warm chunk {i} exchanged nothing");
        // Same values move through the fabric either way.
        assert_eq!(e.total_loads(), r.total_loads(), "chunk {i} load count");
    }
}

#[test]
fn depth1_star_1d_slab_exchange_matches_reload_bitwise() {
    let _g = lock();
    let spec = StencilSpec::dim1(40, symmetric_taps(2)).unwrap();
    let mut rng = XorShift::new(0x4A10_EE1D);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(3)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Host);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 3, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn depth1_box_3d_block_exchange_matches_reload_bitwise() {
    let _g = lock();
    let mut rng = XorShift::new(0xB0C5_EE01);
    let spec = StencilSpec::box3d(10, 9, 8, 1, 1, 1, coeffs(&mut rng, 27)).unwrap();
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Host);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 2, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn fused_depth2_star_2d_slab_exchange_matches_reload_bitwise() {
    let _g = lock();
    // ny = 6 caps the trapezoid at depth 2 (needs ny > 2T), so steps = 4
    // compiles to two depth-2 chunks: one cold, one warm.
    let spec = StencilSpec::heat2d(30, 6, 0.2);
    let mut rng = XorShift::new(0x5AB0_EE02);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert_eq!(probe.fused_steps(), 2, "geometry must cap the depth at 2");
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        assert!(e.reports.iter().all(|rep| rep.ring_points > 0));
    }
}

#[test]
fn fused_depth3_star_3d_pencil_exchange_matches_reload_bitwise() {
    let _g = lock();
    // nz = 8 caps the trapezoid at depth 3, so 4 steps never fuse into a
    // single chunk — a warm chunk (and a narrower tail) is guaranteed.
    let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
    let mut rng = XorShift::new(0x9E4C_EE03);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Pencil)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert!((2..=3).contains(&probe.fused_steps()));
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn fused_box_2d_block_exchange_matches_reload_bitwise() {
    let _g = lock();
    let mut rng = XorShift::new(0xB0CE_EE04);
    let spec = StencilSpec::box2d(20, 8, 1, 1, coeffs(&mut rng, 9)).unwrap();
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Spatial);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
    }
}

#[test]
fn acceptance_pencil_16_tile_3d_warm_chunks_read_zero_dram() {
    let _g = lock();
    // The headline acceptance pin: a 16-tile pencil 3-D plan (the
    // acoustic shape: cuts [1, 4, 4], radius 2) under `exchange` drives
    // post-warm-up redundant reads to exactly 0 — well under the 0.01
    // budget — while staying bitwise-equal to reload and the oracle.
    let spec = StencilSpec::dim3(16, 20, 12, symmetric_taps(2), y_taps(2), z_taps(2))
        .unwrap();
    let mut rng = XorShift::new(0xAC16_EE05);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(16)
        .with_decomp(DecompKind::Pencil)
        .with_fuse(FuseMode::Host);
    let probe = compile(&spec, 3, &base).unwrap();
    assert_eq!(probe.plan().tiles.len(), 16, "4 y-cuts x 4 z-cuts");
    assert_eq!(probe.plan().cuts, [1, 4, 4]);
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 3, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        for rep in &e.reports[1..] {
            assert!(rep.redundant_read_fraction <= 0.01);
            assert_eq!(rep.dram_point_reads(), 0);
        }
        // Reload keeps paying the geometric overlap every chunk.
        assert!(r.reports.iter().all(|rep| rep.redundant_read_fraction > 0.0));
    }
}

#[test]
fn acceptance_block_2d_warm_chunks_read_zero_dram() {
    let _g = lock();
    let spec = StencilSpec::heat2d(24, 8, 0.2);
    let mut rng = XorShift::new(0xB10C_EE06);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Spatial);
    let probe = compile(&spec, 4, &base).unwrap();
    assert!(probe.total_chunks() >= 2, "ny = 8 caps the depth below 4");
    for core in [SimCore::Event, SimCore::Dense] {
        let (e, r) = run_pair(&spec, 4, &base, &x, core);
        assert_exchange_accounting(&e, &r);
        for rep in &e.reports[1..] {
            assert!(rep.redundant_read_fraction <= 0.01);
            assert_eq!(rep.dram_point_reads(), 0);
        }
    }
}

#[test]
fn exchange_does_zero_extra_planning_or_graph_work() {
    let _g = lock();
    // The schedules are pure index arithmetic built at compile time:
    // compiling under `exchange` does exactly the same plan/graph work
    // as `reload`, and exchange executions build nothing at all.
    let spec = StencilSpec::heat2d(26, 8, 0.2);
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_fuse(FuseMode::Spatial);

    let (p0, g0) = (metrics::plans(), metrics::graph_builds());
    let exchange = Arc::new(compile(&spec, 4, &base.clone().with_halo(HaloMode::Exchange)).unwrap());
    let (p1, g1) = (metrics::plans(), metrics::graph_builds());
    let _reload = compile(&spec, 4, &base.clone().with_halo(HaloMode::Reload)).unwrap();
    let (p2, g2) = (metrics::plans(), metrics::graph_builds());
    assert_eq!(p1 - p0, p2 - p1, "exchange compile plans extra");
    assert_eq!(g1 - g0, g2 - g1, "exchange compile builds extra graphs");

    let mut rng = XorShift::new(0x0EE0_EE07);
    let x = rng.normal_vec(spec.grid_points());
    let machine = exchange.options.machine.clone();
    let session = Session::new(exchange, machine);
    let a = session.run(&x).unwrap();
    let b = session.run(&x).unwrap();
    let (p3, g3) = (metrics::plans(), metrics::graph_builds());
    assert_eq!(p3, p2, "exchange run must not plan");
    assert_eq!(g3, g2, "exchange run must not build graphs");
    assert_eq!(a.output, b.output);
}

#[test]
fn priced_free_and_reload_are_bitwise_identical_across_cores_and_exec_modes() {
    let _g = lock();
    // The full pricing matrix: hop-priced exchange, flat exchange and
    // reload must produce the same bits as the iterated oracle on both
    // sim cores and both execution backends. Pricing shows up only in
    // the accounting: priced warm chunks carry a positive hop-cycle
    // surcharge; the free flavour and reload never do.
    let spec = StencilSpec::heat2d(24, 8, 0.2);
    let mut rng = XorShift::new(0x3B17_EE08);
    let x = rng.normal_vec(spec.grid_points());
    let want = stencil_ref_steps(&spec, &x, 4);
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Spatial);
    for core in [SimCore::Event, SimCore::Dense] {
        for exec in [ExecMode::Pooled, ExecMode::Sequential] {
            let mut outs = Vec::new();
            for halo in [HaloMode::Exchange, HaloMode::ExchangeFree, HaloMode::Reload] {
                let opts = base.clone().with_halo(halo);
                let compiled = Arc::new(compile(&spec, 4, &opts).unwrap());
                let machine = compiled.options.machine.clone();
                let out = Session::new(compiled, machine)
                    .with_sim_core(core)
                    .with_exec(exec)
                    .run(&x)
                    .unwrap();
                assert_eq!(
                    out.output, want,
                    "core={core} exec={exec:?} halo={halo}: oracle mismatch"
                );
                outs.push(out);
            }
            let (priced, free, reload) = (&outs[0], &outs[1], &outs[2]);
            assert!(priced.reports.len() >= 2, "need warm chunks to price");
            assert!(
                priced.reports[1..]
                    .iter()
                    .all(|r| r.exchanged_hop_cycles() > 0),
                "core={core} exec={exec:?}: priced warm chunks must pay hops"
            );
            for (label, out) in [("free", free), ("reload", reload)] {
                assert!(
                    out.reports.iter().all(|r| r.exchanged_hop_cycles() == 0),
                    "core={core} exec={exec:?}: {label} run priced something"
                );
            }
            // Pricing never changes what is shipped, only when it lands.
            for (p, f) in priced.reports.iter().zip(&free.reports) {
                assert_eq!(p.exchanged_points, f.exchanged_points);
                assert_eq!(p.total_loads(), f.total_loads());
            }
        }
    }
}

#[test]
fn far_neighbors_price_strictly_higher_than_near_on_one_plan() {
    let _g = lock();
    // A 2x2 block plan has both face neighbors (1 mesh hop) and the
    // diagonal (2 hops) inside one schedule; the channel model must
    // price the far transfer strictly above the near one.
    let spec = StencilSpec::heat2d(26, 18, 0.2);
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(4)
        .with_decomp(DecompKind::Block)
        .with_fuse(FuseMode::Host);
    let compiled = compile(&spec, 2, &base).unwrap();
    let machine = &compiled.options.machine;
    let plan = compiled.plan();
    assert_eq!((plan.cuts[0], plan.cuts[1]), (2, 2), "need a 2x2 mesh");
    let sched = ExchangeSchedule::build(&spec, plan, plan);
    let hops: Vec<usize> = sched
        .tiles
        .iter()
        .flat_map(|te| te.from_tiles.iter().map(|t| t.mesh_hops))
        .collect();
    assert!(hops.contains(&1), "no face-neighbor transfer: {hops:?}");
    assert!(hops.contains(&2), "no diagonal transfer: {hops:?}");
    let near = mesh_hop_cycles(1, machine);
    let far = mesh_hop_cycles(2, machine);
    assert!(near > 0, "even one mesh hop crosses the PE grid");
    assert!(
        far > near,
        "diagonal ({far} cyc) must out-price the face neighbor ({near} cyc)"
    );
}

#[test]
fn ring_overlap_reports_max_not_sum_and_never_reorders_the_trace() {
    let _g = lock();
    // Fused chunks with a boundary ring: the bands overlap the fused
    // batch in pooled mode, so the chunk makespan is
    // max(fused makespan, ring critical path) — recomputable from the
    // report — never the old fused + Σ(band maxima) serialization. The
    // overlap must be timing-only: the recorded trace (phase 0 = fused,
    // phases 1.. = bands, in task order) is bitwise identical between
    // the pooled/overlapped and sequential backends.
    // ny = 6 caps the trapezoid at depth 2 (needs ny > 2T), so steps = 4
    // compiles to two depth-2 chunks — every chunk has a ring.
    let spec = StencilSpec::heat2d(30, 6, 0.2);
    let mut rng = XorShift::new(0x0F17_EE09);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Spatial);
    let compiled = Arc::new(compile(&spec, 4, &base).unwrap());
    assert_eq!(compiled.fused_steps(), 2, "geometry must cap the depth at 2");
    let machine = compiled.options.machine.clone();
    let session = Session::new(compiled, machine);
    let (pooled, pooled_trace) = session.run_recorded(&x).unwrap();
    let (seq, seq_trace) = session
        .clone()
        .with_exec(ExecMode::Sequential)
        .run_recorded(&x)
        .unwrap();
    assert_eq!(pooled.output, seq.output);
    assert_eq!(
        pooled_trace.records, seq_trace.records,
        "overlap must not reorder or change the trace"
    );
    for (i, r) in pooled.reports.iter().enumerate() {
        assert!(r.ring_points > 0, "chunk {i} has no ring to overlap");
        assert!(r.ring_critical_cycles > 0, "chunk {i} ring ran for free");
        let fused_max = r.per_tile.iter().map(|t| t.cycles).max().unwrap();
        assert_eq!(
            r.makespan_cycles,
            fused_max.max(r.ring_critical_cycles),
            "chunk {i}: makespan must be the overlapped max, not a sum"
        );
        assert!(
            r.makespan_cycles < fused_max + r.ring_critical_cycles,
            "chunk {i}: ring still serializes behind the fused batch"
        );
    }
}

#[test]
fn forced_spill_falls_back_to_reload_and_reports_it() {
    let _g = lock();
    // A tile whose input box overflows the fabric token budget cannot
    // stay resident: it must transparently fall back to the cache/DRAM
    // reload path (bitwise-identical values) while the report carries
    // the spill explicitly — and the reported spilled points must equal
    // the DRAM point reads actually measured on the warm chunks
    // (read-once per input point at depth 1).
    let spec = StencilSpec::heat2d(24, 8, 0.2);
    let mut rng = XorShift::new(0x5F11_EE0A);
    let x = rng.normal_vec(spec.grid_points());
    let base = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_decomp(DecompKind::Slab)
        .with_fuse(FuseMode::Host);
    let clean = Arc::new(compile(&spec, 3, &base).unwrap());
    let mut spilled = compile(&spec, 3, &base).unwrap();
    let st = &mut spilled.stages[0];
    st.residency.resident[0] = false;
    st.residency.spilled_points = st.plan.tiles[0].in_points();
    let expect_spill = st.residency.spilled_points as u64;
    let spilled = Arc::new(spilled);

    let machine = clean.options.machine.clone();
    let a = Session::new(Arc::clone(&clean), machine.clone()).run(&x).unwrap();
    let b = Session::new(Arc::clone(&spilled), machine).run(&x).unwrap();
    assert_eq!(a.output, b.output, "spilling must not change the values");
    assert_eq!(b.output, stencil_ref_steps(&spec, &x, 3));

    assert!(!a.reports[0].exchange_spilled, "cold chunks never spill");
    assert_eq!(a.reports[0].spilled_points, 0);
    for (i, (c, s)) in a.reports.iter().zip(&b.reports).enumerate().skip(1) {
        assert!(!c.exchange_spilled, "clean warm chunk {i} spilled");
        assert_eq!(c.dram_point_reads(), 0);
        assert!(s.exchange_spilled, "spilled warm chunk {i} not flagged");
        assert_eq!(s.spilled_points, expect_spill, "warm chunk {i}");
        assert_eq!(
            s.dram_point_reads(),
            expect_spill,
            "warm chunk {i}: reported spill != measured DRAM reads"
        );
        assert!(
            s.exchanged_points < c.exchanged_points,
            "warm chunk {i}: the spilled tile must stop exchanging"
        );
        assert!(
            s.exchanged_points > 0,
            "warm chunk {i}: the resident tile must keep exchanging"
        );
    }
}
