//! Artifact-runtime integration: every manifest artifact loads and
//! executes, and the results agree with the native oracles. The default
//! backend is the native interpreter (see `runtime`'s module docs);
//! with a PJRT backend the same assertions exercise the JAX/Pallas
//! lowerings.

use stencil_cgra::runtime::Runtime;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{
    heat2d_step_ref, max_abs_diff, stencil1d_ref, stencil2d_ref, stencil_ref_steps,
};

fn rt() -> Runtime {
    Runtime::open(Runtime::default_dir())
        .expect("rust/artifacts/manifest.txt missing or unreadable")
}

#[test]
fn manifest_lists_all_experiment_artifacts() {
    let rt = rt();
    let names = rt.names();
    for required in [
        "stencil1d_r1_n256",
        "stencil1d_r8_n4096",
        "stencil1d_r8_n194400",
        "stencil2d_r2_64x64",
        "stencil2d_r12_96x96",
        "stencil2d_ref_r12_96x96",
        "heat2d_step_96x96",
        "heat2d_run200_96x96",
    ] {
        assert!(names.contains(&required), "missing artifact {required}");
    }
}

#[test]
fn every_artifact_compiles() {
    let rt = rt();
    let names: Vec<String> = rt.names().iter().map(|s| s.to_string()).collect();
    for name in names {
        let meta = rt.meta(&name).unwrap().clone();
        // Execute with zero inputs of the right shapes — must not error.
        let zeros: Vec<Vec<f64>> = meta
            .in_shapes
            .iter()
            .map(|s| vec![0.0; s.iter().product()])
            .collect();
        let refs: Vec<&[f64]> = zeros.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute(&name, &refs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), meta.out_shape.iter().product::<usize>(), "{name}");
    }
}

#[test]
fn artifact_1d_matches_native_oracle() {
    let rt = rt();
    let mut rng = XorShift::new(42);
    let x = rng.normal_vec(4096);
    let c = symmetric_taps(8);
    let out = rt.execute("stencil1d_r8_n4096", &[&x, &c]).unwrap();
    let want = stencil1d_ref(&x, &c);
    assert!(max_abs_diff(&out, &want) < 1e-12);
}

#[test]
fn artifact_2d_matches_native_oracle() {
    let rt = rt();
    let mut rng = XorShift::new(43);
    let x = rng.normal_vec(96 * 96);
    let cx = symmetric_taps(12);
    let cy = y_taps(12);
    let out = rt.execute("stencil2d_r12_96x96", &[&x, &cx, &cy]).unwrap();
    let spec = StencilSpec::dim2(96, 96, cx, cy).unwrap();
    let want = stencil2d_ref(&x, &spec);
    assert!(max_abs_diff(&out, &want) < 1e-12);
}

#[test]
fn kernel_and_reference_artifacts_agree() {
    // The kernel-vs-ref check done in pytest, repeated through the runtime:
    // both artifacts must produce identical results.
    let rt = rt();
    let mut rng = XorShift::new(44);
    let x = rng.normal_vec(96 * 96);
    let cx = symmetric_taps(12);
    let cy = y_taps(12);
    let a = rt.execute("stencil2d_r12_96x96", &[&x, &cx, &cy]).unwrap();
    let b = rt.execute("stencil2d_ref_r12_96x96", &[&x, &cx, &cy]).unwrap();
    assert!(max_abs_diff(&a, &b) < 1e-12);
}

#[test]
fn heat_step_artifact_matches_oracle() {
    let rt = rt();
    let mut rng = XorShift::new(45);
    let x = rng.normal_vec(96 * 96);
    let out = rt.execute("heat2d_step_96x96", &[&x]).unwrap();
    let want = heat2d_step_ref(&x, 96, 96, 0.2);
    assert!(max_abs_diff(&out, &want) < 1e-12);
}

#[test]
fn heat_run200_is_200_fused_steps() {
    // §IV temporal locality: the fused 200-step artifact equals 200
    // applications of the single-step oracle.
    let rt = rt();
    let mut x = vec![0.0; 96 * 96];
    x[48 * 96 + 48] = 100.0; // hot spot
    let fused = rt.execute("heat2d_run200_96x96", &[&x]).unwrap();
    let want = stencil_ref_steps(&StencilSpec::heat2d(96, 96, 0.2), &x, 200);
    assert!(max_abs_diff(&fused, &want) < 1e-10);
    // Physics: the peak decayed, heat spread, maximum principle held.
    assert!(fused[48 * 96 + 48] < 100.0);
    assert!(fused[40 * 96 + 48] > 0.0);
}

#[test]
fn full_scale_1d_artifact_runs() {
    // The Table-I grid (194400 points) end to end through the runtime.
    let rt = rt();
    let mut rng = XorShift::new(46);
    let x = rng.normal_vec(194400);
    let c = symmetric_taps(8);
    let out = rt.execute("stencil1d_r8_n194400", &[&x, &c]).unwrap();
    let want = stencil1d_ref(&x, &c);
    assert!(max_abs_diff(&out, &want) < 1e-12);
}

#[test]
fn wrong_input_count_is_a_clean_error() {
    let rt = rt();
    let x = vec![0.0; 256];
    assert!(rt.execute("stencil1d_r1_n256", &[&x]).is_err());
}

#[test]
fn wrong_input_shape_is_a_clean_error() {
    let rt = rt();
    let x = vec![0.0; 100]; // wrong length
    let c = vec![0.0; 3];
    assert!(rt.execute("stencil1d_r1_n256", &[&x, &c]).is_err());
}
