//! Serve-path tests for the compile-once/execute-many API: one
//! `Arc<CompiledStencil>` executed concurrently from many threads must
//! be bitwise-equal to sequential runs on both simulator cores, and a
//! saved/loaded artifact must execute identically to the in-memory one.

use std::sync::Arc;

use stencil_cgra::cgra::{Machine, SimCore};
use stencil_cgra::compile::{compile, CompileOptions, CompiledStencil, FuseMode};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{max_abs_diff, stencil_ref_steps};

#[test]
fn session_and_compiled_stencil_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Session>();
    assert_send_sync::<CompiledStencil>();
    assert_send_sync::<Arc<CompiledStencil>>();
}

/// N threads, one shared artifact, distinct inputs: every thread's
/// output and cycle counts must equal the sequential reference run,
/// bitwise, on both scheduler cores.
#[test]
fn concurrent_runs_bitwise_equal_sequential_on_both_cores() {
    let spec = StencilSpec::heat2d(32, 18, 0.2);
    let steps = 2;
    let opts = CompileOptions::default().with_workers(2).with_tiles(4);
    let compiled = Arc::new(compile(&spec, steps, &opts).unwrap());

    let inputs: Vec<Vec<f64>> = (0..4)
        .map(|i| XorShift::new(0xA110 + i as u64).normal_vec(spec.grid_points()))
        .collect();

    for core in [SimCore::Dense, SimCore::Event] {
        let session = Session::new(Arc::clone(&compiled), Machine::paper()).with_sim_core(core);

        // Sequential reference.
        let sequential: Vec<_> = inputs.iter().map(|x| session.run(x).unwrap()).collect();

        // Concurrent: all four inputs at once through the same &Session.
        let session_ref = &session;
        let concurrent: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = inputs
                .iter()
                .map(|x| scope.spawn(move || session_ref.run(x).unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (i, (seq, conc)) in sequential.iter().zip(&concurrent).enumerate() {
            assert_eq!(seq.output, conc.output, "core {core}, input {i}");
            assert_eq!(seq.reports.len(), conc.reports.len());
            for (a, b) in seq.reports.iter().zip(&conc.reports) {
                assert_eq!(a.output, b.output, "core {core}, input {i}");
                assert_eq!(a.makespan_cycles, b.makespan_cycles);
                assert_eq!(a.total_cycles, b.total_cycles);
            }
            // And both match the iterated oracle.
            let want = stencil_ref_steps(&spec, &inputs[i], steps);
            assert!(max_abs_diff(&conc.output, &want) < 1e-11, "core {core}");
        }
    }
}

/// The two cores remain bit-identical through the session path.
#[test]
fn session_cores_agree_bitwise() {
    let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
    let opts = CompileOptions::default().with_workers(2).with_tiles(4);
    let compiled = Arc::new(compile(&spec, 1, &opts).unwrap());
    let x = XorShift::new(0xC0FE).normal_vec(spec.grid_points());
    let dense = Session::new(Arc::clone(&compiled), Machine::paper())
        .with_sim_core(SimCore::Dense)
        .run(&x)
        .unwrap();
    let event = Session::new(Arc::clone(&compiled), Machine::paper())
        .with_sim_core(SimCore::Event)
        .run(&x)
        .unwrap();
    assert_eq!(dense.output, event.output);
    assert_eq!(dense.reports[0].makespan_cycles, event.reports[0].makespan_cycles);
}

/// Round-trip pin: a loaded artifact executes bitwise-identically to
/// the artifact it was saved from — including a fused multi-stage
/// schedule with a tail chunk.
#[test]
fn saved_artifact_executes_identically_after_load() {
    let spec = StencilSpec::heat2d(28, 20, 0.2);
    let steps = 5;
    let opts = CompileOptions::default()
        .with_workers(2)
        .with_tiles(2)
        .with_fuse(FuseMode::Spatial);
    let compiled = compile(&spec, steps, &opts).unwrap();

    let path = std::env::temp_dir().join(format!("scgra_roundtrip_{}.txt", std::process::id()));
    compiled.save(&path).unwrap();
    let loaded = CompiledStencil::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.spec, compiled.spec);
    assert_eq!(loaded.steps, compiled.steps);
    assert_eq!(loaded.workers, compiled.workers);
    assert_eq!(loaded.stages.len(), compiled.stages.len());
    for (a, b) in loaded.stages.iter().zip(&compiled.stages) {
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.repeats, b.repeats);
    }

    let x = XorShift::new(0x10AD).normal_vec(spec.grid_points());
    let mem = Session::new(Arc::new(compiled), Machine::paper()).run(&x).unwrap();
    let disk = Session::new(Arc::new(loaded), Machine::paper()).run(&x).unwrap();
    assert_eq!(mem.output, disk.output, "loaded artifact must execute bitwise");
    assert_eq!(mem.reports.len(), disk.reports.len());
    for (a, b) in mem.reports.iter().zip(&disk.reports) {
        assert_eq!(a.output, b.output);
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.fused_steps, b.fused_steps);
    }
}
