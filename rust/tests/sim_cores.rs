//! Cross-core differential suite: the event-driven ready-list core must
//! be **bit-identical** to the dense reference loop — same output grid,
//! same cycle count, same firing counters, same memory statistics — on
//! every workload family the mapper supports (star 1-D/2-D/3-D, box
//! 2-D/3-D, temporal multi-step, instruction-packed tiny fabrics) and
//! through the multi-tile coordinator (pencil-cut 3-D included).
//!
//! The dense loop is the executable specification; the event core is
//! the optimization. Any divergence here is a scheduler bug, not a
//! tolerance question — everything is compared with `==`.
//!
//! The session matrix at the bottom extends the differential across the
//! execution engines (persistent pool vs in-thread sequential) and the
//! trace recorder: every data-dependent observable — outputs, per-task
//! cycles, fire counts/hashes, memory counters — is identical across
//! dense/event x pooled/sequential, and a trace recorded under any
//! combination replays cleanly under every other.

use std::sync::Arc;

use stencil_cgra::cgra::{Machine, SimCore, Simulator};
use stencil_cgra::compile::{compile, CompileOptions};
use stencil_cgra::coordinator::{Coordinator, FuseMode};
use stencil_cgra::session::{ExecMode, RunOutcome, Session};
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::{build_graph, temporal, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::run_sim_core;

/// Run `spec` on both cores and assert every observable is identical.
/// Returns (dense skipped, event skipped) for workload-specific checks.
fn assert_cores_identical(spec: &StencilSpec, w: usize, m: &Machine, seed: u64) -> (u64, u64) {
    let mut rng = XorShift::new(seed);
    let x = rng.normal_vec(spec.grid_points());
    let dense = run_sim_core(spec, w, m, &x, SimCore::Dense).unwrap();
    let event = run_sim_core(spec, w, m, &x, SimCore::Event).unwrap();
    let label = format!("spec dims {:?} w={w}", spec.dims());
    assert_eq!(dense.output, event.output, "{label}: output grids differ");
    assert_eq!(
        dense.stats.cycles, event.stats.cycles,
        "{label}: cycle counts differ"
    );
    assert_eq!(dense.stats.mem, event.stats.mem, "{label}: MemStats differ");
    assert_eq!(
        dense.stats.total_fires(),
        event.stats.total_fires(),
        "{label}: fire totals differ"
    );
    assert_eq!(dense.stats.dp_fires, event.stats.dp_fires, "{label}");
    assert_eq!(
        dense.stats.fires_control, event.stats.fires_control,
        "{label}"
    );
    assert_eq!(dense.stats.fires_reader, event.stats.fires_reader, "{label}");
    assert_eq!(
        dense.stats.fires_compute, event.stats.fires_compute,
        "{label}"
    );
    assert_eq!(dense.stats.fires_writer, event.stats.fires_writer, "{label}");
    assert_eq!(dense.stats.fires_sync, event.stats.fires_sync, "{label}");
    assert_eq!(
        dense.stats.max_queue_occupancy, event.stats.max_queue_occupancy,
        "{label}: queue occupancy differs"
    );
    assert_eq!(
        dense.stats.fire_hash, event.stats.fire_hash,
        "{label}: (node, cycle) fire sequences differ"
    );
    assert_eq!(dense.stats.skipped_cycles, 0, "{label}: dense never skips");
    assert!(
        event.stats.wakeups <= event.stats.cycles * event.stats.node_count as u64,
        "{label}: at most one wakeup per node per cycle"
    );
    (dense.stats.skipped_cycles, event.stats.skipped_cycles)
}

#[test]
fn star_1d_cores_identical() {
    let m = Machine::paper();
    for (nx, r, w) in [(96usize, 1usize, 1usize), (200, 8, 6), (301, 3, 4)] {
        let spec = StencilSpec::dim1(nx, symmetric_taps(r)).unwrap();
        let (_, skipped) = assert_cores_identical(&spec, w, &m, 0xC0DE + nx as u64);
        // The DRAM ramp alone guarantees idle cycles to skip.
        assert!(skipped > 0, "1-D nx={nx} should skip idle cycles");
    }
}

#[test]
fn star_2d_cores_identical() {
    let m = Machine::paper();
    let spec = StencilSpec::dim2(40, 24, symmetric_taps(2), y_taps(2)).unwrap();
    assert_cores_identical(&spec, 3, &m, 0x2D);
    let heat = StencilSpec::heat2d(32, 20, 0.2);
    assert_cores_identical(&heat, 2, &m, 0x2E);
}

#[test]
fn star_3d_cores_identical() {
    let m = Machine::paper();
    let spec = StencilSpec::heat3d(12, 10, 8, 0.1);
    assert_cores_identical(&spec, 2, &m, 0x3D);
    let wide = StencilSpec::dim3(14, 10, 8, symmetric_taps(2), y_taps(1), z_taps(1)).unwrap();
    assert_cores_identical(&wide, 2, &m, 0x3E);
}

#[test]
fn box_2d_and_3d_cores_identical() {
    let m = Machine::paper();
    let b2 = StencilSpec::box2d(24, 18, 1, 1, uniform_box_taps(1, 1, 0)).unwrap();
    assert_cores_identical(&b2, 2, &m, 0xB2);
    let b3 = StencilSpec::box3d(10, 8, 6, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap();
    assert_cores_identical(&b3, 1, &m, 0xB3);
}

#[test]
fn temporal_multistep_cores_identical() {
    // §IV temporal pipelines have the deepest chains and the most
    // instruction-level idling — the cycle-skipping sweet spot.
    let m = Machine::paper();
    let spec = StencilSpec::dim1(160, vec![0.25, 0.5, 0.25]).unwrap();
    let mut rng = XorShift::new(0x7E4);
    let x = rng.normal_vec(160);
    for steps in [2usize, 3] {
        let run = |core: SimCore| {
            let g = temporal::build(&spec, 2, steps).unwrap();
            Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .run()
                .unwrap()
        };
        let dense = run(SimCore::Dense);
        let event = run(SimCore::Event);
        assert_eq!(dense.output, event.output, "steps={steps}");
        assert_eq!(dense.stats.cycles, event.stats.cycles, "steps={steps}");
        assert_eq!(dense.stats.mem, event.stats.mem, "steps={steps}");
        assert_eq!(
            dense.stats.total_fires(),
            event.stats.total_fires(),
            "steps={steps}"
        );
    }
}

#[test]
fn temporal_nd_2d_cores_identical() {
    // The generalized §IV pipeline: deep cross-layer graphs with
    // row-buffer delay lines between layers — the event core must stay
    // bit-identical through the inter-layer backpressure.
    let m = Machine::paper();
    let spec = StencilSpec::dim2(20, 14, symmetric_taps(1), y_taps(2)).unwrap();
    let mut rng = XorShift::new(0x7E5A);
    let x = rng.normal_vec(20 * 14);
    for steps in [2usize, 3] {
        let run = |core: SimCore| {
            let g = temporal::build_nd(&spec, 2, steps).unwrap();
            Simulator::build(g, &m, x.clone(), x.clone())
                .unwrap()
                .with_core(core)
                .run()
                .unwrap()
        };
        let dense = run(SimCore::Dense);
        let event = run(SimCore::Event);
        assert_eq!(dense.output, event.output, "steps={steps}");
        assert_eq!(dense.stats.cycles, event.stats.cycles, "steps={steps}");
        assert_eq!(dense.stats.mem, event.stats.mem, "steps={steps}");
        assert_eq!(
            dense.stats.total_fires(),
            event.stats.total_fires(),
            "steps={steps}"
        );
        assert_eq!(
            dense.stats.max_queue_occupancy, event.stats.max_queue_occupancy,
            "steps={steps}"
        );
    }
}

#[test]
fn multitile_fused_run_steps_cores_identical() {
    // Spatially-fused coordinator chunks across both cores: stitched
    // grids and cycle sums must match bit-for-bit.
    let spec = StencilSpec::dim2(28, 18, symmetric_taps(2), y_taps(1)).unwrap();
    let mut rng = XorShift::new(0xA4F);
    let x = rng.normal_vec(28 * 18);
    let run = |core: SimCore| {
        Coordinator::new(2, Machine::paper())
            .with_fuse(FuseMode::Spatial)
            .with_sim_core(core)
            .run_steps(&spec, 2, &x, 3)
            .unwrap()
    };
    let (dout, dreps) = run(SimCore::Dense);
    let (eout, ereps) = run(SimCore::Event);
    assert_eq!(dout, eout, "stitched grids differ");
    assert_eq!(dreps.len(), ereps.len());
    let cycles =
        |rs: &[stencil_cgra::coordinator::RunReport]| -> u64 {
            rs.iter().map(|r| r.total_cycles).sum()
        };
    assert_eq!(cycles(&dreps), cycles(&ereps), "cycle sums differ");
    let loads = |rs: &[stencil_cgra::coordinator::RunReport]| -> u64 {
        rs.iter().map(|r| r.total_loads()).sum()
    };
    assert_eq!(loads(&dreps), loads(&ereps), "load counts differ");
}

#[test]
fn packed_tiny_fabric_cores_identical() {
    // Machine::tiny forces several instructions per PE, exercising the
    // one-instruction-per-PE-per-cycle arbitration replay (group sweep
    // + suppressed-mate re-arm) rather than the flat topological path.
    let m = Machine::tiny();
    let spec = StencilSpec::dim1(48, vec![0.25, 0.5, 0.25]).unwrap();
    let mut rng = XorShift::new(0x717);
    let x = rng.normal_vec(48);
    let run = |core: SimCore| {
        let g = build_graph(&spec, 2).unwrap();
        Simulator::build(g, &m, x.clone(), x.clone())
            .unwrap()
            .with_core(core)
            .run()
            .unwrap()
    };
    let dense = run(SimCore::Dense);
    let event = run(SimCore::Event);
    assert_eq!(dense.output, event.output);
    assert_eq!(dense.stats.cycles, event.stats.cycles);
    assert_eq!(dense.stats.mem, event.stats.mem);
    assert_eq!(dense.stats.total_fires(), event.stats.total_fires());
    assert_eq!(dense.stats.max_queue_occupancy, event.stats.max_queue_occupancy);
}

/// Deterministic multi-tile aggregates: which hardware tile ran which
/// task depends on thread scheduling, but the *set* of tile tasks and
/// each task's simulation are deterministic — so the stitched grid,
/// the total cycle sum and the array-wide memory counters must be
/// bit-identical across cores.
fn assert_coordinator_cores_identical(
    spec: &StencilSpec,
    w: usize,
    tiles: usize,
    kind: DecompKind,
    seed: u64,
) {
    let mut rng = XorShift::new(seed);
    let x = rng.normal_vec(spec.grid_points());
    let run = |core: SimCore| {
        Coordinator::new(tiles, Machine::paper())
            .with_decomp(kind)
            .with_sim_core(core)
            .run(spec, w, &x)
            .unwrap()
    };
    let dense = run(SimCore::Dense);
    let event = run(SimCore::Event);
    assert_eq!(dense.output, event.output, "stitched grids differ");
    assert_eq!(dense.strips, event.strips);
    assert_eq!(dense.total_cycles, event.total_cycles, "cycle sums differ");
    assert_eq!(dense.halo_points, event.halo_points);
    let sum_mem = |rep: &stencil_cgra::coordinator::RunReport| {
        let mut acc = stencil_cgra::cgra::stats::MemStats::default();
        for t in &rep.per_tile {
            acc.accumulate(&t.mem);
        }
        acc
    };
    assert_eq!(sum_mem(&dense), sum_mem(&event), "array MemStats differ");
}

#[test]
fn multitile_1d_slab_cores_identical() {
    let spec = StencilSpec::dim1(300, symmetric_taps(4)).unwrap();
    assert_coordinator_cores_identical(&spec, 2, 3, DecompKind::Auto, 0xA1);
}

#[test]
fn multitile_2d_slab_cores_identical() {
    let spec = StencilSpec::dim2(64, 20, symmetric_taps(2), y_taps(2)).unwrap();
    assert_coordinator_cores_identical(&spec, 2, 4, DecompKind::Slab, 0xA2);
}

#[test]
fn multitile_3d_pencil_cores_identical() {
    let spec = StencilSpec::dim3(14, 10, 8, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap();
    assert_coordinator_cores_identical(&spec, 2, 4, DecompKind::Pencil, 0xA3);
}

// ---------------------------------------------------------------------------
// Session matrix: dense/event x pooled/sequential x trace replay.
//
// Under the greedy persistent pool, *which worker* runs which tile task
// depends on thread scheduling, so `makespan_cycles` and the per-tile
// attribution (`per_tile`, `TileReport`) are scheduling-dependent and
// deliberately excluded. Everything data-dependent — the stitched
// output, the summed task cycles, the array-wide memory counters, the
// per-task fingerprints a trace records — must be `==` across all four
// combinations.
// ---------------------------------------------------------------------------

fn session_matrix_fixture() -> (Session, Vec<f64>) {
    let spec = StencilSpec::dim2(32, 20, symmetric_taps(2), y_taps(1)).unwrap();
    let opts = CompileOptions::default()
        .with_workers(2)
        .with_tiles(3)
        .with_fuse(FuseMode::Spatial);
    let compiled = Arc::new(compile(&spec, 3, &opts).unwrap());
    let machine = compiled.options.machine.clone();
    let mut rng = XorShift::new(0x5E55);
    let x = rng.normal_vec(spec.grid_points());
    (Session::new(compiled, machine), x)
}

const COMBOS: [(SimCore, ExecMode); 4] = [
    (SimCore::Dense, ExecMode::Pooled),
    (SimCore::Dense, ExecMode::Sequential),
    (SimCore::Event, ExecMode::Pooled),
    (SimCore::Event, ExecMode::Sequential),
];

fn sum_cycles(o: &RunOutcome) -> u64 {
    o.reports.iter().map(|r| r.total_cycles).sum()
}

fn sum_mem(o: &RunOutcome) -> stencil_cgra::cgra::stats::MemStats {
    let mut acc = stencil_cgra::cgra::stats::MemStats::default();
    for rep in &o.reports {
        for t in &rep.per_tile {
            acc.accumulate(&t.mem);
        }
        acc.accumulate(&rep.ring_mem);
    }
    acc
}

#[test]
fn session_exec_modes_and_cores_bitwise_identical() {
    let (base, x) = session_matrix_fixture();
    let runs: Vec<(String, RunOutcome)> = COMBOS
        .iter()
        .map(|&(core, exec)| {
            let s = base.clone().with_sim_core(core).with_exec(exec);
            (format!("{core}/{exec:?}"), s.run(&x).unwrap())
        })
        .collect();
    let (ref_name, reference) = &runs[0];
    for (name, o) in &runs[1..] {
        assert_eq!(
            o.output, reference.output,
            "{name} vs {ref_name}: stitched grids differ"
        );
        assert_eq!(
            sum_cycles(o),
            sum_cycles(reference),
            "{name} vs {ref_name}: summed task cycles differ"
        );
        assert_eq!(
            sum_mem(o),
            sum_mem(reference),
            "{name} vs {ref_name}: array MemStats differ"
        );
        assert_eq!(o.reports.len(), reference.reports.len());
        for (a, b) in o.reports.iter().zip(&reference.reports) {
            assert_eq!(a.strips, b.strips, "{name}: task counts differ");
            assert_eq!(
                a.dram_point_reads(),
                b.dram_point_reads(),
                "{name}: DRAM point reads differ"
            );
            assert_eq!(
                a.exchanged_points, b.exchanged_points,
                "{name}: exchange accounting differs"
            );
        }
    }
}

#[test]
fn trace_recorded_under_any_combo_replays_under_every_other() {
    let (base, x) = session_matrix_fixture();
    // Record once per combination: per-task cycles, fires, tickets and
    // fire/output hashes are scheduling-independent, so all four traces
    // are identical and each replays against each.
    let traces: Vec<_> = COMBOS
        .iter()
        .map(|&(core, exec)| {
            let s = base.clone().with_sim_core(core).with_exec(exec);
            let (_, t) = s.run_recorded(&x).unwrap();
            t
        })
        .collect();
    for (i, t) in traces.iter().enumerate().skip(1) {
        assert_eq!(
            t, &traces[0],
            "trace under {:?} differs from {:?}",
            COMBOS[i], COMBOS[0]
        );
    }
    for &(core, exec) in &COMBOS {
        let s = base.clone().with_sim_core(core).with_exec(exec);
        s.run_replay(&x, &traces[0]).unwrap();
    }
}

#[test]
fn tampered_trace_fails_replay_with_the_divergent_field() {
    let (base, x) = session_matrix_fixture();
    let (_, trace) = base.run_recorded(&x).unwrap();
    let mut tampered = trace.clone();
    tampered.records[0].output_hash ^= 1;
    let err = base.run_replay(&x, &tampered).unwrap_err().to_string();
    assert!(err.contains("output_hash"), "{err}");
    let mut short = trace;
    short.records.pop();
    let err = base.run_replay(&x, &short).unwrap_err().to_string();
    assert!(err.contains("length mismatch"), "{err}");
}
