//! Cross-module integration: mapper -> placement -> simulator -> verify,
//! the §IV temporal pipeline, asm round-trips through the simulator, and
//! coordinator/simulator equivalence.

use stencil_cgra::cgra::{Machine, Simulator};
use stencil_cgra::coordinator::Coordinator;
use stencil_cgra::dfg::asm;
use stencil_cgra::roofline;
use stencil_cgra::stencil::spec::{symmetric_taps, y_taps};
use stencil_cgra::stencil::{map1d, map2d, temporal, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::{
    max_abs_diff, run_sim, stencil1d_ref, stencil2d_ref, stencil_ref_steps,
};

#[test]
fn temporal_pipeline_computes_multiple_steps_on_fabric() {
    // §IV: T time-steps in one kernel call, no intermediate memory
    // round-trip. Valid region shrinks by rx per step (trapezoid).
    let spec = StencilSpec::dim1(120, vec![0.25, 0.5, 0.25]).unwrap();
    let mut rng = XorShift::new(0xB00);
    let x = rng.normal_vec(120);
    for steps in [1usize, 2, 3] {
        for w in [1usize, 2, 3] {
            let g = temporal::build(&spec, w, steps).unwrap();
            let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
                .unwrap()
                .run()
                .unwrap();
            // Iterated full-grid oracle.
            let want = stencil_ref_steps(&spec, &x, steps);
            let (lo, hi) = temporal::valid_range(&spec, steps);
            for i in lo..hi {
                assert!(
                    (res.output[i] - want[i]).abs() < 1e-11,
                    "steps={steps} w={w} i={i}: {} vs {}",
                    res.output[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn temporal_pipeline_reads_input_once() {
    // The whole point of §IV: input loaded once regardless of depth.
    let spec = StencilSpec::dim1(200, vec![0.3, 0.4, 0.3]).unwrap();
    let x = vec![1.0; 200];
    for steps in [1usize, 3] {
        let g = temporal::build(&spec, 2, steps).unwrap();
        let res = Simulator::build(g, &Machine::paper(), x.clone(), x.clone())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(res.stats.mem.loads, 200, "steps={steps}");
        // DP work scales with depth.
        assert!(res.stats.dp_fires >= (steps as u64) * 3 * (200 - 2 * steps as u64));
    }
}

#[test]
fn asm_round_trip_simulates_identically() {
    // §V: the emitted assembly program is a faithful representation —
    // parse it back and the simulation matches the in-memory graph.
    let spec = StencilSpec::dim2(24, 16, symmetric_taps(2), y_taps(1)).unwrap();
    let mut rng = XorShift::new(0xA5);
    let x = rng.normal_vec(24 * 16);

    let g1 = map2d::build(&spec, 2).unwrap();
    let text = asm::to_asm(&g1, "round-trip");
    let g2 = asm::parse(&text).unwrap();

    let m = Machine::paper();
    let r1 = Simulator::build(g1, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    let r2 = Simulator::build(g2, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    assert_eq!(r1.output, r2.output);
    assert_eq!(r1.stats.cycles, r2.stats.cycles);
}

#[test]
fn coordinator_equals_single_simulator() {
    // Tile-decomposed multi-tile execution must be numerically identical
    // to one whole-grid simulation.
    let spec = StencilSpec::dim2(72, 20, symmetric_taps(3), y_taps(2)).unwrap();
    let mut rng = XorShift::new(0xE0);
    let x = rng.normal_vec(72 * 20);
    let whole = run_sim(&spec, 2, &Machine::paper(), &x).unwrap();
    let coord = Coordinator::new(4, Machine::paper());
    let rep = coord.run(&spec, 2, &x).unwrap();
    assert!(max_abs_diff(&whole.output, &rep.output) < 1e-12);
}

#[test]
fn roofline_chosen_workers_beat_fewer_workers() {
    // Ablation sanity: the §VI-optimal worker count is at least as fast
    // as half of it on the real simulator.
    let spec = StencilSpec::dim1(20000, symmetric_taps(8)).unwrap();
    let m = Machine::paper();
    let w_opt = roofline::optimal_workers(&spec, &m); // 6
    let x = vec![1.0; 20000];
    let fast = run_sim(&spec, w_opt, &m, &x).unwrap();
    let slow = run_sim(&spec, (w_opt / 2).max(1), &m, &x).unwrap();
    assert!(
        fast.stats.cycles < slow.stats.cycles,
        "w={w_opt}: {} !< {}",
        fast.stats.cycles,
        slow.stats.cycles
    );
}

#[test]
fn achieved_gflops_close_to_roofline_on_table1_shapes() {
    // Scaled-down Table-I shapes: the simulator should reach a large
    // fraction of the bandwidth roofline (the paper reports 91% / 78%).
    let m = Machine::paper();

    let s1 = StencilSpec::dim1(40000, symmetric_taps(8)).unwrap();
    let r1 = run_sim(&s1, 6, &m, &vec![1.0; 40000]).unwrap();
    let g1 = r1.gflops(s1.total_flops(), m.clock_ghz);
    let roof1 = m.roofline_gflops(s1.arithmetic_intensity());
    assert!(g1 / roof1 > 0.8, "1D: {g1:.0} of {roof1:.0}");

    let s2 = StencilSpec::dim2(240, 113, symmetric_taps(12), y_taps(12)).unwrap();
    let r2 = run_sim(&s2, 5, &m, &vec![1.0; 240 * 113]).unwrap();
    let g2 = r2.gflops(s2.total_flops(), m.clock_ghz);
    let roof2 = m.roofline_gflops(s2.arithmetic_intensity());
    assert!(g2 / roof2 > 0.6, "2D: {g2:.0} of {roof2:.0}");
}

#[test]
fn filter_scheme_ablation_bits_vs_rowcol_same_result() {
    // 1-D mapping uses bit patterns; building the same stencil as a
    // degenerate 2-D (ny > 2ry) with row/col filters must agree on the
    // common interior.
    let n = 60;
    let cx = symmetric_taps(2);
    let spec1 = StencilSpec::dim1(n, cx.clone()).unwrap();
    let mut rng = XorShift::new(0xF1);
    let x = rng.normal_vec(n);

    let r1 = run_sim(&spec1, 3, &Machine::paper(), &x).unwrap();
    let want = stencil1d_ref(&x, &cx);
    assert!(max_abs_diff(&r1.output, &want) < 1e-12);

    // Same row repeated as a 2-D grid with zero y-coefficients.
    let ny = 5;
    let spec2 = StencilSpec::dim2(n, ny, cx, vec![0.0, 0.0]).unwrap();
    let x2: Vec<f64> = (0..ny).flat_map(|_| x.clone()).collect();
    let r2 = run_sim(&spec2, 3, &Machine::paper(), &x2).unwrap();
    let mid = 2; // interior row
    for c in spec2.rx..n - spec2.rx {
        assert!(
            (r2.output[mid * n + c] - want[c]).abs() < 1e-12,
            "col {c}"
        );
    }
}

#[test]
fn undersized_delay_line_deadlocks() {
    // §III-B mandatory buffering, failure injection at the graph level:
    // shrink only the delay-line stages and the 2-D pipeline wedges.
    let spec = StencilSpec::dim2(40, 20, symmetric_taps(1), y_taps(4)).unwrap();
    let mut g = map2d::build(&spec, 1).unwrap();
    for n in &g.nodes.clone() {
        if n.op == stencil_cgra::dfg::Op::Copy {
            let ch = g.input(n.id, 0).unwrap();
            g.channels[ch].capacity = 2;
        }
    }
    let x = vec![1.0; 40 * 20];
    let err = Simulator::build(g, &Machine::paper(), x.clone(), x)
        .unwrap()
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("deadlock"), "{err}");
}

#[test]
fn dfg_stats_match_fig7_and_fig11() {
    // Fig 7: 17-pt, 6 workers, 102 DP ops. Fig 11: 49-pt, 5 workers.
    let g1 = map1d::build(&StencilSpec::paper_1d(), 6).unwrap();
    assert_eq!(g1.dp_ops(), 102);
    let g2 = map2d::build(&StencilSpec::paper_2d(), 5).unwrap();
    assert_eq!(g2.dp_ops(), 245);
    // Dot emission for both (what `scgra dfg --dot` writes).
    let dot = stencil_cgra::dfg::dot::to_dot(&g1, "fig7");
    assert!(dot.contains("102 DP ops"));
}
