//! Textual snapshot tests for the §V emitters on a small 3-D spec: the
//! `dfg::asm` and `dfg::dot` output is pinned line-by-line (structure,
//! immediates, mandatory-buffering capacities and counts), so any
//! regression in DFG emission — node naming, filter/agen encoding,
//! capacity assignment, channel ordering — is caught textually.
//!
//! The spec is tiny and fully hand-analyzable: a 7-pt 3-D star on a
//! 6x5x4 grid, one worker. Alignment stage = rz*ny + ry = 6, delay line
//! depth = 2*rz*ny + ry = 11 stages.

use stencil_cgra::cgra::{Machine, Simulator};
use stencil_cgra::dfg::{asm, dot};
use stencil_cgra::stencil::{map3d, temporal, StencilSpec};
use stencil_cgra::util::rng::XorShift;

fn snapshot_spec() -> StencilSpec {
    StencilSpec::dim3(
        6,
        5,
        4,
        vec![0.25, 0.5, 0.25],
        vec![0.125, 0.125],
        vec![0.0625, 0.0625],
    )
    .unwrap()
}

#[test]
fn asm_snapshot_3d_star() {
    let g = map3d::build(&snapshot_spec(), 1).unwrap();
    let text = asm::to_asm(&g, "snapshot3d");
    let lines: Vec<&str> = text.lines().collect();

    // Header + 31 pe lines + 36 chan lines.
    assert_eq!(lines[0], "# tia-asm: snapshot3d");
    assert_eq!(lines[1], "# 31 nodes, 36 channels, 7 DP ops");
    assert_eq!(lines.len(), 2 + 31 + 36, "full emission:\n{text}");
    assert_eq!(lines.iter().filter(|l| l.starts_with("pe ")).count(), 31);
    assert_eq!(lines.iter().filter(|l| l.starts_with("chan ")).count(), 36);

    // Reader control unit: flat row-major sweep of the whole volume
    // (nz*ny = 20 flattened rows, width 6, flat-mode zeros).
    assert!(
        text.contains("pe r0.cu agen stage=control agen=0,20,0,6,1,6,0,0,0"),
        "{text}"
    );
    // Delay line runs to exactly stage 11 (2*rz*ny + ry).
    assert!(text.contains("pe r0.copy11 copy stage=reader"));
    assert!(!text.contains("pe r0.copy12"));

    // Tap filters carry the volume windows, shifted per tap offset.
    for want in [
        // x taps (dz=0, dy=0, dx=-1/0/+1).
        "pe w0.f0 filter stage=compute worker=0 filter=vol:1,3,1,4,0,4,5",
        "pe w0.f1 filter stage=compute worker=0 filter=vol:1,3,1,4,1,5,5",
        "pe w0.f2 filter stage=compute worker=0 filter=vol:1,3,1,4,2,6,5",
        // y taps (dy = -1, +1).
        "pe w0.f3 filter stage=compute worker=0 filter=vol:1,3,0,3,1,5,5",
        "pe w0.f4 filter stage=compute worker=0 filter=vol:1,3,2,5,1,5,5",
        // z taps (dz = -1, +1) shift the z window.
        "pe w0.f5 filter stage=compute worker=0 filter=vol:0,2,1,4,1,5,5",
        "pe w0.f6 filter stage=compute worker=0 filter=vol:2,4,1,4,1,5,5",
    ] {
        assert!(text.contains(want), "missing `{want}` in:\n{text}");
    }

    // Chain immediates (1 MUL + 6 MACs, coefficients in chain order).
    assert!(text.contains("pe w0.mul mul stage=compute worker=0 coeff=2.5e-1"));
    assert!(text.contains("pe w0.mac1 mac stage=compute worker=0 coeff=5e-1"));
    assert!(text.contains("pe w0.mac3 mac stage=compute worker=0 coeff=1.25e-1"));
    assert!(text.contains("pe w0.mac6 mac stage=compute worker=0 coeff=6.25e-2"));

    // Writer control unit uses the plane-mode (9-field) agen over the
    // interior z [1,3), y [1,4), cols [1,5).
    assert!(
        text.contains("pe w0.st.cu agen stage=control agen=1,3,1,5,1,6,1,4,5"),
        "{text}"
    );
    // Sync counts the 4 * 3 * 2 = 24 interior outputs.
    assert!(text.contains("pe w0.sync sync stage=sync worker=0 expected=24"));
    assert!(text.contains("pe done done stage=sync expected=1"));

    // Channel wiring: taps read the delay line at their alignment stage
    // (x taps at d6 = copy6), and mandatory chain capacities are
    // 2k + 2rx/w + 4.
    assert!(text.contains("chan 12 r0.copy6:0 -> w0.f0:0 cap=4 lat=1"));
    assert!(text.contains("chan 13 w0.f0:0 -> w0.mul:0 cap=6 lat=1"));
    assert!(text.contains("chan 16 w0.f1:0 -> w0.mac1:1 cap=8 lat=1"));
    // The deepest tap (dz = -1) reads a full plane later: stage 11.
    assert!(text.contains("r0.copy11:0 -> w0.f5:0 cap=4 lat=1"));
    // The shallowest (dz = +1) reads stage 1.
    assert!(text.contains("r0.copy1:0 -> w0.f6:0 cap=4 lat=1"));
}

#[test]
fn dot_snapshot_3d_star() {
    let g = map3d::build(&snapshot_spec(), 1).unwrap();
    let text = dot::to_dot(&g, "snapshot3d");
    assert!(text.starts_with("digraph dfg {"));
    assert!(text.contains("label=\"snapshot3d\\n31 nodes, 36 channels, 7 DP ops\";"));
    assert!(text.contains("cluster_w0"));
    // Fig 7 legend colors: mul orange, mac red, filter plum, agen cyan.
    assert!(text.contains("fillcolor=orange"));
    assert!(text.contains("fillcolor=red"));
    assert!(text.contains("fillcolor=plum"));
    assert!(text.contains("fillcolor=cyan"));
    // One edge per channel; non-default capacities are labelled.
    assert_eq!(text.matches("->").count(), g.channel_count());
    assert!(text.contains("[label=\"cap=6\"]"));
    assert!(text.contains("[label=\"cap=8\"]"));
    assert!(text.trim_end().ends_with('}'));
}

/// A tiny fully hand-analyzable 2-D temporal pipeline: 5-pt star on an
/// 8x6 grid, one worker, two fused layers. Chain-tap order is x
/// (-1, 0, +1) then y (-1, +1); the last tap's offset (0, +1, 0) is the
/// per-layer tag shift, so layer 1's filter windows sit one row below
/// layer 0's. Delay lines are 2*ry = 2 stages per stream; layer 0's
/// stage holds a full 8-column row (cap 12), layer 1's the 6-column
/// interior row (cap 10).
fn temporal_snapshot_spec() -> StencilSpec {
    StencilSpec::dim2(8, 6, vec![0.25, 0.5, 0.25], vec![0.125, 0.125]).unwrap()
}

#[test]
fn asm_snapshot_2d_temporal() {
    let g = temporal::build_nd(&temporal_snapshot_spec(), 1, 2).unwrap();
    let text = asm::to_asm(&g, "temporal2d");
    let lines: Vec<&str> = text.lines().collect();

    // Header + 30 pe lines + 37 chan lines: reader pair, 2 delay copies
    // + 10 chain ops per layer x 2 layers, writer trio + done.
    assert_eq!(lines[0], "# tia-asm: temporal2d");
    assert_eq!(lines[1], "# 30 nodes, 37 channels, 10 DP ops");
    assert_eq!(lines.len(), 2 + 30 + 37, "full emission:\n{text}");
    assert_eq!(lines.iter().filter(|l| l.starts_with("pe ")).count(), 30);
    assert_eq!(lines.iter().filter(|l| l.starts_with("chan ")).count(), 37);

    // One reader sweeping the whole grid; no second load anywhere.
    assert!(
        text.contains("pe r0.cu agen stage=control agen=0,6,0,8,1,8,0,0,0"),
        "{text}"
    );
    assert_eq!(lines.iter().filter(|l| l.contains(" ld ")).count(), 1);

    // Both layers carry a 2-stage delay line; no stage 3 exists.
    assert!(text.contains("pe s0.0.copy2 copy stage=reader"));
    assert!(text.contains("pe s1.0.copy2 copy stage=reader"));
    assert!(!text.contains("copy3"));

    // Layer 0 filters are the plain §III-B windows...
    for want in [
        "pe l0.w0.f0 filter stage=compute worker=0 filter=rowcol:1,5,0,6",
        "pe l0.w0.f1 filter stage=compute worker=0 filter=rowcol:1,5,1,7",
        "pe l0.w0.f2 filter stage=compute worker=0 filter=rowcol:1,5,2,8",
        "pe l0.w0.f3 filter stage=compute worker=0 filter=rowcol:0,4,1,7",
        "pe l0.w0.f4 filter stage=compute worker=0 filter=rowcol:2,6,1,7",
    ] {
        assert!(text.contains(want), "missing `{want}` in:\n{text}");
    }
    // ...layer 1's shrink by one more radius and shift by the (0,+1,0)
    // tag offset.
    for want in [
        "pe l1.w0.f0 filter stage=compute worker=0 filter=rowcol:3,5,1,5",
        "pe l1.w0.f1 filter stage=compute worker=0 filter=rowcol:3,5,2,6",
        "pe l1.w0.f2 filter stage=compute worker=0 filter=rowcol:3,5,3,7",
        "pe l1.w0.f3 filter stage=compute worker=0 filter=rowcol:2,4,2,6",
        "pe l1.w0.f4 filter stage=compute worker=0 filter=rowcol:4,6,2,6",
    ] {
        assert!(text.contains(want), "missing `{want}` in:\n{text}");
    }

    // Chain immediates repeat per layer.
    assert!(text.contains("pe l0.w0.mul mul stage=compute worker=0 coeff=2.5e-1"));
    assert!(text.contains("pe l1.w0.mac1 mac stage=compute worker=0 coeff=5e-1"));
    assert!(text.contains("pe l1.w0.mac4 mac stage=compute worker=0 coeff=1.25e-1"));

    // Writers store the 4x2 valid trapezoid box only.
    assert!(
        text.contains("pe w0.st.cu agen stage=control agen=2,4,2,6,1,8,0,0,0"),
        "{text}"
    );
    assert!(text.contains("pe w0.sync sync stage=sync worker=0 expected=8"));

    // Inter-layer wiring: layer 0's chain output feeds layer 1's delay
    // line (one interior row + slack) and the dy=+1 tap at stage 0;
    // the reader feeds layer 0 the same way with a full-row stage.
    assert!(text.contains("r0.ld:0 -> s0.0.copy1:0 cap=12 lat=1"));
    assert!(text.contains("r0.ld:0 -> l0.w0.f4:0 cap=4 lat=1"));
    assert!(text.contains("l0.w0.mac4:0 -> s1.0.copy1:0 cap=10 lat=1"));
    assert!(text.contains("l0.w0.mac4:0 -> l1.w0.f4:0 cap=4 lat=1"));
    // Mandatory chain capacities: 2k + 2rx/w + 4.
    assert!(text.contains("l0.w0.f0:0 -> l0.w0.mul:0 cap=6 lat=1"));
    assert!(text.contains("l1.w0.f1:0 -> l1.w0.mac1:1 cap=8 lat=1"));
}

#[test]
fn dot_snapshot_2d_temporal() {
    let g = temporal::build_nd(&temporal_snapshot_spec(), 1, 2).unwrap();
    let text = dot::to_dot(&g, "temporal2d");
    assert!(text.starts_with("digraph dfg {"));
    assert!(text.contains("label=\"temporal2d\\n30 nodes, 37 channels, 10 DP ops\";"));
    assert!(text.contains("cluster_w0"));
    assert_eq!(text.matches("->").count(), g.channel_count());
    assert!(text.trim_end().ends_with('}'));
}

#[test]
fn asm_round_trip_simulates_identically_2d_temporal() {
    let spec = temporal_snapshot_spec();
    let mut rng = XorShift::new(0x7E2D);
    let x = rng.normal_vec(spec.grid_points());
    let g1 = temporal::build_nd(&spec, 1, 2).unwrap();
    let text = asm::to_asm(&g1, "round-trip-temporal");
    let g2 = asm::parse(&text).unwrap();
    let m = Machine::paper();
    let r1 = Simulator::build(g1, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    let r2 = Simulator::build(g2, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    assert_eq!(r1.output, r2.output);
    assert_eq!(r1.stats.cycles, r2.stats.cycles);
}

#[test]
fn asm_round_trip_simulates_identically_3d() {
    let spec = snapshot_spec();
    let mut rng = XorShift::new(0x5A95);
    let x = rng.normal_vec(spec.grid_points());
    let g1 = map3d::build(&spec, 1).unwrap();
    let text = asm::to_asm(&g1, "round-trip-3d");
    let g2 = asm::parse(&text).unwrap();
    let m = Machine::paper();
    let r1 = Simulator::build(g1, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    let r2 = Simulator::build(g2, &m, x.clone(), x.clone()).unwrap().run().unwrap();
    assert_eq!(r1.output, r2.output);
    assert_eq!(r1.stats.cycles, r2.stats.cycles);
}
