//! Acceptance suite for the `scgra check` static verifier
//! (`stencil_cgra::analysis`).
//!
//! Two halves, mirroring the analyzer's contract:
//!
//! * **Clean sweep** — every artifact `compile` can produce must carry
//!   zero diagnostics, errors *and* warnings, across star/box shapes,
//!   1/2/3-D ranks, slab/pencil/block decompositions, and single-step /
//!   fused / tail-stage step counts. This is load-bearing: debug builds
//!   run Error-level checking inside `compile` itself, so a single
//!   false positive would fail the whole test suite.
//! * **Mutation pins** — each rule family must catch a seeded defect
//!   and report the *exact* rule id and location, the way a register
//!   file test pins one bit at a time: an underbuffered channel cycle
//!   (`deadlock/cycle-buffering`), a dropped halo transfer
//!   (`exchange/coverage`), a zero-bandwidth boundary link
//!   (`exchange/link-capacity`), a fabric budget the residency plan
//!   contradicts (`capacity/resident-overflow`, `capacity/needless-
//!   spill`), and a tile box escaping the grid (`plan/halo-bounds`).
//!
//! The final test closes the loop the ISSUE demands: fixtures that pass
//! the static deadlock rules also run to completion under the runtime
//! quiet-period detector — the dynamic check the `deadlock/*` family is
//! the static analogue of.

use std::sync::Arc;

use stencil_cgra::analysis::deadlock::fundamental_cycles;
use stencil_cgra::analysis::{check, CheckLevel, Severity};
use stencil_cgra::compile::{compile, CompileOptions, CompiledStencil};
use stencil_cgra::session::Session;
use stencil_cgra::stencil::decomp::DecompKind;
use stencil_cgra::stencil::spec::{symmetric_taps, uniform_box_taps, y_taps, z_taps};
use stencil_cgra::stencil::StencilSpec;
use stencil_cgra::util::rng::XorShift;

fn opts(tiles: usize, kind: DecompKind) -> CompileOptions {
    CompileOptions::default()
        .with_workers(2)
        .with_tiles(tiles)
        .with_decomp(kind)
}

/// The standard mutation fixture: two slab tiles of a radius-2 1-D
/// star, two fused steps — small, but with real halo transfers, a
/// residency plan, placed graphs with reconvergent channel paths, and
/// (depth permitting) a boundary ring.
fn two_tile_1d() -> CompiledStencil {
    let spec = StencilSpec::dim1(96, symmetric_taps(2)).unwrap();
    compile(&spec, 2, &opts(2, DecompKind::Slab)).unwrap()
}

#[test]
fn clean_sweep_across_shapes_ranks_and_decompositions() {
    let cases: Vec<(StencilSpec, DecompKind, usize)> = vec![
        (StencilSpec::dim1(96, symmetric_taps(2)).unwrap(), DecompKind::Slab, 4),
        (
            StencilSpec::dim2(28, 20, symmetric_taps(1), y_taps(1)).unwrap(),
            DecompKind::Slab,
            2,
        ),
        (
            StencilSpec::dim2(32, 24, symmetric_taps(2), y_taps(2)).unwrap(),
            DecompKind::Block,
            4,
        ),
        (
            StencilSpec::box2d(28, 22, 1, 1, uniform_box_taps(1, 1, 0)).unwrap(),
            DecompKind::Slab,
            2,
        ),
        (
            StencilSpec::dim3(16, 12, 10, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap(),
            DecompKind::Pencil,
            4,
        ),
        (
            StencilSpec::box3d(14, 12, 10, 1, 1, 1, uniform_box_taps(1, 1, 1)).unwrap(),
            DecompKind::Block,
            8,
        ),
    ];
    // steps = 1 (host), 3 (fused + tail stage when depth 2 fits), 4
    // (fused, chunk-aligned) — the three stage-schedule shapes.
    for (spec, kind, tiles) in &cases {
        for steps in [1usize, 3, 4] {
            let c = compile(spec, steps, &opts(*tiles, *kind)).unwrap();
            let report = check(&c);
            assert!(
                report.is_clean(),
                "dims {:?} kind={kind} tiles={tiles} steps={steps} not clean:\n{}",
                spec.dims(),
                report.to_text()
            );
            // The strictest gate passes too — `--deny warn` in CI runs
            // exactly this over the example artifacts.
            report.gate(CheckLevel::Full).unwrap();
        }
    }
}

#[test]
fn the_compile_gate_accepts_full_checking_on_clean_plans() {
    // Explicit Full-level checking inside compile() (stricter than the
    // debug default) on a two-stage fused schedule.
    let spec = StencilSpec::dim2(24, 16, symmetric_taps(1), y_taps(1)).unwrap();
    let o = opts(2, DecompKind::Slab).with_check(CheckLevel::Full);
    let c = compile(&spec, 3, &o).unwrap();
    assert_eq!(c.options.check, CheckLevel::Full);
}

#[test]
fn load_checked_accepts_a_clean_saved_artifact() {
    let c = two_tile_1d();
    let path = std::env::temp_dir().join(format!(
        "scgra_static_check_{}.txt",
        std::process::id()
    ));
    c.save(&path).unwrap();
    let back = CompiledStencil::load_checked(&path, CheckLevel::Full).unwrap();
    assert_eq!(back.options, c.options, "check level survives the round trip");
    std::fs::remove_file(&path).ok();
}

#[test]
fn underbuffering_a_channel_cycle_is_pinned_to_the_buffering_rule() {
    let mut c = two_tile_1d();
    let key = {
        let mut ks: Vec<[usize; 3]> = c.stages[0].graphs.keys().copied().collect();
        ks.sort_unstable();
        ks[0]
    };
    {
        let arc = c.stages[0].graphs.get_mut(&key).unwrap();
        let pg = Arc::get_mut(arc).expect("compile leaves each placed graph unshared");
        let cycles = fundamental_cycles(pg);
        assert!(!cycles.is_empty(), "1-D mapped graphs have reconvergent paths");
        // Shrink EVERY channel on one fundamental cycle to capacity ==
        // latency. One channel alone is not enough: placement gives the
        // others `capacity >= latency + 2`, whose summed slack covers a
        // single missing in-flight slot.
        for &e in &cycles[0] {
            let lat = pg.channels()[e].latency() as usize;
            pg.override_channel_capacity(e, lat);
        }
    }
    let report = check(&c);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "deadlock/cycle-buffering")
        .unwrap_or_else(|| panic!("buffering rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.location.stage, Some(0));
    let obj = d.location.object.as_deref().unwrap();
    assert!(obj.starts_with("graph "), "location names the placed graph: {obj}");
    assert!(d.evidence.contains("chan"), "evidence lists the cycle: {}", d.evidence);
    // The same shrink also trips the per-channel streaming floor.
    assert!(report.diagnostics.iter().any(|d| d.rule == "deadlock/streaming-floor"));
    assert!(report.gate(CheckLevel::Errors).is_err());
}

#[test]
fn dropping_a_transfer_is_pinned_to_the_coverage_rule() {
    let mut c = two_tile_1d();
    let ex = &mut c.stages[0].intra_exchange.tiles[0];
    assert!(!ex.from_tiles.is_empty(), "two slab tiles exchange halos");
    ex.from_tiles.remove(0);
    let report = check(&c);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "exchange/coverage")
        .unwrap_or_else(|| panic!("coverage rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.location.stage, Some(0));
    assert_eq!(d.location.tile, Some(0));
    // The partition total `resident + exchanged == in_points` breaks
    // with the missing transfer — the promoted builder assertion.
    assert!(
        report.diagnostics.iter().any(|d| d.rule == "exchange/resident-accounting"),
        "{}",
        report.to_text()
    );
}

#[test]
fn zero_link_bandwidth_is_pinned_to_the_link_capacity_rule() {
    let mut c = two_tile_1d();
    assert!(c.stages[0].intra_exchange.exchanged_points() > 0);
    c.options.machine.link_words_per_cycle = 0;
    let report = check(&c);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "exchange/link-capacity")
        .unwrap_or_else(|| panic!("link rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.location.stage, Some(0));
    assert_eq!(d.location.object.as_deref(), Some("intra-exchange"));
}

#[test]
fn lying_about_the_fabric_budget_is_pinned_to_resident_overflow() {
    let mut c = two_tile_1d();
    assert!(
        c.stages[0].residency.resident.iter().all(|&r| r),
        "fixture is fully resident under the default budget"
    );
    c.options.fabric_tokens = 0;
    let report = check(&c);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "capacity/resident-overflow")
        .unwrap_or_else(|| panic!("overflow rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.location.stage, Some(0));
    assert_eq!(d.location.tile, Some(0));
    assert!(report.gate(CheckLevel::Errors).is_err());
}

#[test]
fn a_needless_spill_warns_but_passes_the_error_gate() {
    let mut c = two_tile_1d();
    // A *consistent* lie: tile 0 spills although it fits, and the
    // recorded spill total says so. Only the Warn-level policy rule can
    // object — which is exactly the `--deny warn` distinction.
    let in_pts = c.stages[0].plan.tiles[0].in_points();
    c.stages[0].residency.resident[0] = false;
    c.stages[0].residency.spilled_points += in_pts;
    let report = check(&c);
    assert_eq!(report.error_count(), 0, "{}", report.to_text());
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "capacity/needless-spill")
        .unwrap_or_else(|| panic!("spill rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Warn);
    assert_eq!(d.location.tile, Some(0));
    report.gate(CheckLevel::Errors).unwrap();
    assert!(report.gate(CheckLevel::Full).is_err(), "deny-warn rejects it");
}

#[test]
fn an_out_of_grid_tile_is_pinned_to_halo_bounds() {
    let mut c = two_tile_1d();
    let nx = c.spec.nx;
    c.stages[0].plan.tiles[0].in_hi[0] = nx + 3;
    let report = check(&c);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "plan/halo-bounds")
        .unwrap_or_else(|| panic!("bounds rule silent:\n{}", report.to_text()));
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.location.stage, Some(0));
    assert_eq!(d.location.tile, Some(0));
}

#[test]
fn static_deadlock_verdict_matches_the_runtime_detector() {
    // Fixtures shaped like the cross-core differential suite's: the
    // runtime quiet-period detector (`deadlock: no progress ...`) runs
    // over exactly these placed graphs. A clean `deadlock/*` verdict
    // must mean the simulation completes — if it ever deadlocked, the
    // static analogue missed a cycle and this test fails loudly.
    let cases: Vec<(StencilSpec, usize, usize)> = vec![
        (StencilSpec::dim1(64, symmetric_taps(2)).unwrap(), 1, 1),
        (
            StencilSpec::dim2(24, 16, symmetric_taps(1), y_taps(1)).unwrap(),
            2,
            2,
        ),
        (
            StencilSpec::dim3(12, 10, 8, symmetric_taps(1), y_taps(1), z_taps(1)).unwrap(),
            2,
            1,
        ),
    ];
    for (spec, tiles, steps) in cases {
        let c = compile(&spec, steps, &opts(tiles, DecompKind::Auto)).unwrap();
        let report = check(&c);
        assert!(
            report.diagnostics.iter().all(|d| !d.rule.starts_with("deadlock/")),
            "dims {:?}: {}",
            spec.dims(),
            report.to_text()
        );
        let machine = c.options.machine.clone();
        let mut rng = XorShift::new(5);
        let input = rng.normal_vec(spec.grid_points());
        if let Err(e) = Session::new(Arc::new(c), machine).run(&input) {
            panic!(
                "dims {:?}: runtime failed ({}) although the static deadlock \
                 verdict was clean: {e}",
                spec.dims(),
                e.kind()
            );
        }
    }
}
