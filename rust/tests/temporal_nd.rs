//! Differential suite for the §IV N-dim temporal pipeline
//! (`temporal::build_nd`): seeded-random 2-D/3-D star and box specs ×
//! fused depths 1–4 × both scheduler cores, compared **bitwise** (`==`,
//! not a tolerance) against the iterated golden oracle
//! (`verify::golden::stencil_ref_steps`) on the valid trapezoid box —
//! the fused pipeline runs the exact `chain_taps` f64 association order
//! the oracle uses, so any difference is a mapping bug. Plus the §IV
//! load-count pin (input read exactly once regardless of depth,
//! extending `tests/sim_integration.rs`'s 1-D version), the capacity
//! accounting pin (`temporal::required_tokens` equals the built graph's
//! mandatory queue capacities), and the coordinator-level contract:
//! spatially-fused multi-tile runs are bitwise-equal to the oracle on
//! the **full grid** (the time-tiled ring stages cover the boundary
//! band the raw trapezoid leaves out) and load strictly less than the
//! host-driven loop at equal steps. The raw-pipeline checks here stay
//! on the valid box on purpose — the ring belongs to the session layer,
//! not to `build_nd`.

use stencil_cgra::cgra::{Machine, SimCore, Simulator};
use stencil_cgra::coordinator::{Coordinator, FuseMode};
use stencil_cgra::dfg::Op;
use stencil_cgra::stencil::spec::uniform_box_taps;
use stencil_cgra::stencil::{temporal, StencilSpec};
use stencil_cgra::util::rng::XorShift;
use stencil_cgra::verify::golden::stencil_ref_steps;

/// Random coefficient in roughly [-0.5, 0.5] — bounded so iterated
/// accumulations stay well-conditioned.
fn coeffs(rng: &mut XorShift, n: usize) -> Vec<f64> {
    (0..n).map(|_| 0.3 * rng.normal()).collect()
}

/// Simulate the fused pipeline on one core and assert bitwise equality
/// with the iterated oracle on the valid trapezoid box.
fn assert_fused_matches_oracle(
    spec: &StencilSpec,
    w: usize,
    steps: usize,
    x: &[f64],
    core: SimCore,
) {
    let m = Machine::paper();
    let g = temporal::build_nd(spec, w, steps).unwrap();
    let res = Simulator::build(g, &m, x.to_vec(), x.to_vec())
        .unwrap()
        .with_core(core)
        .run()
        .unwrap();
    let want = stencil_ref_steps(spec, x, steps);
    let (lo, hi) = temporal::valid_box(spec, steps);
    let label = format!(
        "dims {:?} radii {:?} w={w} steps={steps} core={core}",
        spec.dims(),
        spec.radii()
    );
    let mut checked = 0usize;
    for z in lo[2]..hi[2] {
        for y in lo[1]..hi[1] {
            for c in lo[0]..hi[0] {
                let i = (z * spec.ny + y) * spec.nx + c;
                assert_eq!(
                    res.output[i], want[i],
                    "{label}: point (z={z}, y={y}, x={c})"
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "{label}: empty valid box");
}

#[test]
fn star_2d_random_fused_depths_match_iterated_oracle_bitwise() {
    let mut rng = XorShift::new(0x7E40_0001);
    for case in 0..4 {
        let rx = rng.range(1, 3);
        let ry = rng.range(1, 3);
        let steps = rng.range(2, 5);
        let nx = rng.range(2 * rx * steps + 2, 2 * rx * steps + 14);
        let ny = rng.range(2 * ry * steps + 2, 2 * ry * steps + 10);
        let w = rng.range(1, 4);
        let spec = StencilSpec::dim2(
            nx,
            ny,
            coeffs(&mut rng, 2 * rx + 1),
            coeffs(&mut rng, 2 * ry),
        )
        .unwrap();
        let x = rng.normal_vec(nx * ny);
        let core = if case % 2 == 0 { SimCore::Event } else { SimCore::Dense };
        assert_fused_matches_oracle(&spec, w, steps, &x, core);
    }
}

#[test]
fn fixed_2d_star_depth_sweep_both_cores() {
    // Depths 1 through 4 on both cores for one fixed spec, so every
    // depth is covered deterministically.
    let spec = StencilSpec::heat2d(22, 14, 0.2);
    let mut rng = XorShift::new(0x7E40_0002);
    let x = rng.normal_vec(22 * 14);
    for steps in 1..=4 {
        for core in [SimCore::Dense, SimCore::Event] {
            assert_fused_matches_oracle(&spec, 2, steps, &x, core);
        }
    }
}

#[test]
fn star_3d_random_fused_depths_match_iterated_oracle_bitwise() {
    let mut rng = XorShift::new(0x7E40_0003);
    for case in 0..3 {
        let steps = rng.range(2, 4);
        let nx = rng.range(2 * steps + 2, 2 * steps + 8);
        let ny = rng.range(2 * steps + 2, 2 * steps + 6);
        let nz = rng.range(2 * steps + 2, 2 * steps + 5);
        let w = rng.range(1, 3);
        let spec = StencilSpec::dim3(
            nx,
            ny,
            nz,
            coeffs(&mut rng, 3),
            coeffs(&mut rng, 2),
            coeffs(&mut rng, 2),
        )
        .unwrap();
        let x = rng.normal_vec(nx * ny * nz);
        let core = if case % 2 == 0 { SimCore::Event } else { SimCore::Dense };
        assert_fused_matches_oracle(&spec, w, steps, &x, core);
    }
}

#[test]
fn box_2d_and_3d_fused_match_iterated_oracle_bitwise() {
    let mut rng = XorShift::new(0x7E40_0004);
    let b2 = StencilSpec::box2d(16, 12, 1, 1, coeffs(&mut rng, 9)).unwrap();
    let x2 = rng.normal_vec(16 * 12);
    for (steps, core) in [(2usize, SimCore::Event), (3, SimCore::Dense)] {
        assert_fused_matches_oracle(&b2, 2, steps, &x2, core);
    }
    let b3 = StencilSpec::box3d(9, 8, 7, 1, 1, 1, coeffs(&mut rng, 27)).unwrap();
    let x3 = rng.normal_vec(9 * 8 * 7);
    assert_fused_matches_oracle(&b3, 1, 2, &x3, SimCore::Event);
}

#[test]
fn fused_pipeline_reads_input_exactly_once() {
    // §IV's whole point, beyond 1-D: loads == grid points regardless of
    // fused depth, while the DP work grows with every extra layer.
    let m = Machine::paper();
    let spec2 = StencilSpec::heat2d(20, 12, 0.2);
    let x2 = vec![1.0; 20 * 12];
    let mut prev_dp = 0u64;
    for steps in [1usize, 2, 4] {
        let g = temporal::build_nd(&spec2, 2, steps).unwrap();
        let res = Simulator::build(g, &m, x2.clone(), x2.clone())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(res.stats.mem.loads, (20 * 12) as u64, "2-D steps={steps}");
        assert!(res.stats.dp_fires > prev_dp, "2-D steps={steps}: DP work must grow");
        prev_dp = res.stats.dp_fires;
    }
    let spec3 = StencilSpec::heat3d(10, 8, 6, 0.1);
    let x3 = vec![1.0; 10 * 8 * 6];
    for steps in [1usize, 2] {
        let g = temporal::build_nd(&spec3, 2, steps).unwrap();
        let res = Simulator::build(g, &m, x3.clone(), x3.clone())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(res.stats.mem.loads, (10 * 8 * 6) as u64, "3-D steps={steps}");
    }
}

#[test]
fn required_tokens_matches_built_graph_capacities() {
    // The capacity math the fused-depth planner budgets with must be
    // exactly what the built graph carries: delay stages (Copy port 0),
    // Mul port 0 and Mac port 1 — the same pin map2d/map3d maintain for
    // their single-step graphs.
    let cases = [
        (StencilSpec::heat2d(18, 12, 0.2), 2usize, 3usize),
        (StencilSpec::heat3d(10, 8, 6, 0.1), 2, 2),
        (
            StencilSpec::box2d(14, 10, 1, 1, uniform_box_taps(1, 1, 0)).unwrap(),
            2,
            2,
        ),
    ];
    for (spec, w, steps) in cases {
        let g = temporal::build_nd(&spec, w, steps).unwrap();
        let mut got = 0usize;
        for n in &g.nodes {
            match n.op {
                Op::Copy => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mul => got += g.channels[g.input(n.id, 0).unwrap()].capacity,
                Op::Mac => got += g.channels[g.input(n.id, 1).unwrap()].capacity,
                _ => {}
            }
        }
        assert_eq!(
            got,
            temporal::required_tokens(&spec, w, steps),
            "dims {:?} steps={steps}",
            spec.dims()
        );
    }
}

#[test]
fn fused_coordinator_multitile_3d_matches_oracle_and_saves_loads() {
    // Acceptance contract: a `--fuse spatial --steps 4` 3-D multi-tile
    // run is bitwise-equal to the iterated oracle on the FULL grid —
    // valid trapezoid, boundary ring and Dirichlet frame alike — and
    // loads strictly less than the host-driven loop.
    let spec = StencilSpec::heat3d(14, 12, 10, 0.1);
    let mut rng = XorShift::new(0x7E40_0005);
    let x = rng.normal_vec(14 * 12 * 10);
    let steps = 4;
    let host = Coordinator::new(4, Machine::paper());
    let (_, hreps) = host.run_steps(&spec, 2, &x, steps).unwrap();
    let fused = Coordinator::new(4, Machine::paper()).with_fuse(FuseMode::Spatial);
    let (fout, freps) = fused.run_steps(&spec, 2, &x, steps).unwrap();
    assert_eq!(freps.iter().map(|r| r.fused_steps).sum::<usize>(), steps);
    assert!(freps[0].fused_steps > 1, "default budget must admit fusion");
    let want = stencil_ref_steps(&spec, &x, steps);
    for z in 0..spec.nz {
        for y in 0..spec.ny {
            for c in 0..spec.nx {
                let i = (z * spec.ny + y) * spec.nx + c;
                assert_eq!(fout[i], want[i], "(z={z}, y={y}, x={c})");
            }
        }
    }
    let host_loads: u64 = hreps.iter().map(|r| r.total_loads()).sum();
    let fused_loads: u64 = freps.iter().map(|r| r.total_loads()).sum();
    assert!(fused_loads < host_loads, "{fused_loads} !< {host_loads}");
}

#[test]
fn session_auto_fuse_is_full_grid_bitwise_across_shapes() {
    // The satellite-1 regression: `Session::run` under Spatial/Auto used
    // to be correct only inside `temporal::valid_box`; the ring stages
    // must make it bitwise-equal to the host-stepped oracle everywhere.
    use std::sync::Arc;
    use stencil_cgra::compile::{compile, CompileOptions, FuseMode as CFuse};
    use stencil_cgra::session::Session;

    let mut rng = XorShift::new(0x7E40_0006);
    let cases: Vec<(StencilSpec, usize)> = vec![
        (StencilSpec::heat2d(24, 16, 0.2), 5),
        (StencilSpec::heat3d(12, 10, 8, 0.1), 4),
        (
            StencilSpec::box2d(18, 13, 1, 2, coeffs(&mut rng, 15)).unwrap(),
            3,
        ),
    ];
    for (spec, steps) in cases {
        let x = rng.normal_vec(spec.grid_points());
        let want = stencil_ref_steps(&spec, &x, steps);
        for fuse in [CFuse::Spatial, CFuse::Auto] {
            let opts = CompileOptions::default()
                .with_workers(2)
                .with_tiles(2)
                .with_fuse(fuse);
            let compiled = Arc::new(compile(&spec, steps, &opts).unwrap());
            let machine = compiled.options.machine.clone();
            let out = Session::new(compiled, machine).run(&x).unwrap();
            assert_eq!(
                out.output,
                want,
                "dims {:?} steps={steps} fuse={fuse:?}",
                spec.dims()
            );
        }
    }
}
