//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the subset the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait for `Result` and `Option`. Errors are eagerly rendered
//! to strings; context is prepended `"context: cause"` like anyhow's
//! outermost-first `Display`.

use std::fmt;

/// A string-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    /// Construct from a concrete `std::error::Error` value.
    pub fn new<E>(e: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self::msg(&e)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::msg(&e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to failures.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {}", e.into())))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e.into())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err().to_string();
        assert_eq!(e, "flag was false");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let r: Result<u32> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err().to_string();
        assert_eq!(e, "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn anyhow_accepts_expressions() {
        let msg = String::from("dynamic");
        let e = anyhow!(msg);
        assert_eq!(e.to_string(), "dynamic");
    }
}
